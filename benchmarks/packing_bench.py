"""Data-path benchmark: matching-based sequence packing quality + speed
(the second framework integration of the paper's technique)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data import pack_documents, packing_efficiency


def run(scale: str = "small"):
    rows = []
    rng = np.random.default_rng(0)
    for n_docs, seq_len in ((256, 1024), (1024, 4096)):
        docs = [
            rng.integers(1, 50000, size=int(l)).astype(np.int32)
            for l in np.clip(rng.pareto(1.5, n_docs) * 256 + 16, 16, seq_len)
        ]
        t0 = time.perf_counter()
        rows_packed, mask = pack_documents(docs, n_docs // 2, seq_len)
        dt = time.perf_counter() - t0
        eff = packing_efficiency(mask)
        # baseline: one doc per row
        plain = np.zeros((n_docs // 2, seq_len), bool)
        for i in range(n_docs // 2):
            plain[i, : min(len(docs[i]), seq_len)] = True
        rows.append(emit(
            f"packing/docs{n_docs}_seq{seq_len}", dt,
            f"fill={eff:.3f};baseline={packing_efficiency(plain):.3f}"
        ))
    return rows


if __name__ == "__main__":
    run()
