"""Bench-smoke regression gate (CI).

Compares a freshly recorded kernel_bench JSON against the committed baseline
and fails if any gated row (``kernel/windowed_pipeline/*``,
``kernel/distributed_pipeline/*``, ``kernel/boundary_pipeline/*`` or
``kernel/bmatch/*``) regressed beyond the tolerance. Two extra gates ride
along: ``kernel/distributed_pipeline_hooks/*`` (the fault-harness overhead
row, 2% per-prefix tolerance vs the plain pipeline row of the same run) and
a hard zero-check on the recovery fields the fault-free verified bench run
records (nonzero = silently dropped work, a correctness failure).

CI runners and the recording machine differ in absolute speed, so raw
``us_per_call`` comparisons are meaningless across hosts. Each gated row is
therefore NORMALIZED by a same-run sibling (both sides share the engine and
the host, so machine speed cancels): the windowed pipeline by the jnp tiled
matcher of the same graph, the locality-sharded distributed matcher by the
dispersed jnp-local-pass distributed baseline (same forced-4-device
subprocess), and the b-matching router by the same-run
``window_match/tile128`` row (both engine-bound jnp tile passes):

    ratio(run, row) = us(gated_row) / us(norm_row)

and the gate is ``ratio_new <= ratio_baseline * (1 + tolerance)``.

Usage:
    python benchmarks/check_regression.py new.json baseline.json [--tolerance 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys

# gated prefix -> same-run normalization prefix; the _noreorder twin is
# reported but not gated (it exists for the trajectory, and flakes more:
# no reorder => epilogue-dominated timing)
PREFIXES = {
    "kernel/windowed_pipeline/": "kernel/jnp_matcher/",
    "kernel/distributed_pipeline/": "kernel/distributed_jnp_local/",
    # boundary-heavy (no-reorder rmat14, global tier dominant): gates the
    # block-pair epilogue against the same-run jnp tiled matcher
    "kernel/boundary_pipeline/": "kernel/boundary_jnp/",
    # the fault-harness hooks row runs the IDENTICAL compiled work through
    # the harness plumbing (inert FaultPlan + policy epilogue) — normalized
    # by the plain pipeline row of the same run so the gate is exactly
    # "what do the hooks cost", machine speed cancelled
    "kernel/distributed_pipeline_hooks/": "kernel/distributed_pipeline/",
    # state-width A/B: the single-byte default spec normalized by the
    # same-run legacy_i32 twin on the SAME schedule — gates "narrow state
    # must not cost throughput"; the byte-reduction claim itself is the
    # hard STATE_BYTES_FIELDS check below
    "kernel/state_u8/": "kernel/state_legacy_i32/",
}
# per-prefix overrides of the global --tolerance: the hooks row must track
# the plain pipeline row within 2% (DESIGN.md §11 — default-off means free)
PREFIX_TOLERANCE = {
    "kernel/distributed_pipeline_hooks/": 0.02,
}
# recovery fields recorded by the fault-free verified bench run — any
# nonzero value means the matcher silently dropped or corrupted work, which
# is a correctness failure, not a perf regression
RECOVERY_FIELDS = (
    "recovery_attempts", "residual_edges",
    "recovered_matches", "corrupted_cells",
)
# state-width hard gate: the u8 row's recorded state payloads must undercut
# its same-run legacy_i32 twin by at least this factor (DESIGN.md §12 — the
# refactor's memory claim; analytic fields, so no timer noise allowance)
STATE_BYTES_FIELDS = ("vmem_state_bytes", "wire_state_bytes")
STATE_BYTES_MIN_REDUCTION = 3.5
INFO_PREFIXES = {
    "kernel/windowed_pipeline_noreorder/": "kernel/jnp_matcher/",
}
# gated prefix -> one FIXED same-run row (no per-graph suffix): every
# kernel/bmatch/* case normalizes by the single windowed-oracle row
FIXED_NORMS = {
    "kernel/bmatch/": "kernel/window_match/tile128",
}


def _ratios(data: dict, prefixes=PREFIXES, fixed_norms=()) -> dict:
    """Gated-row -> normalized-ratio map. ``prefixes`` pairs a gated prefix
    with a same-suffix normalizer prefix; ``fixed_norms`` pairs a gated
    prefix with ONE fixed normalizer row (pass FIXED_NORMS explicitly on
    gating calls; informational calls leave it empty)."""
    out = {}
    for name, row in data.items():
        for prefix, norm_prefix in prefixes.items():
            if name.startswith(prefix):
                graph = name[len(prefix):]
                norm = data.get(norm_prefix + graph)
                if norm is None:
                    continue
                out[name] = row["us_per_call"] / norm["us_per_call"]
        for prefix, norm_name in dict(fixed_norms).items():
            if name.startswith(prefix):
                norm = data.get(norm_name)
                if norm is None:
                    continue
                out[name] = row["us_per_call"] / norm["us_per_call"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative slowdown of the jnp-normalized ratio")
    args = ap.parse_args()

    with open(args.new_json) as f:
        new_data = json.load(f)
    with open(args.baseline_json) as f:
        base_data = json.load(f)
    new = _ratios(new_data, fixed_norms=FIXED_NORMS)
    base = _ratios(base_data, fixed_norms=FIXED_NORMS)

    info_base = _ratios(base_data, INFO_PREFIXES)
    for name, r in sorted(_ratios(new_data, INFO_PREFIXES).items()):
        b = info_base.get(name)
        print(f"{name}: ratio {r:.3f} vs baseline "
              f"{'%.3f' % b if b is not None else 'n/a'} (informational)")

    failed = []
    for name, row in sorted(new_data.items()):
        bad = {k: row[k] for k in RECOVERY_FIELDS if row.get(k)}
        if bad:
            print(f"{name}: nonzero recovery fields {bad} FAIL")
            failed.append(f"{name}: fault-free run reported {bad}")
    for name, row in sorted(new_data.items()):
        if not name.startswith("kernel/state_u8/"):
            continue
        twin = new_data.get(
            "kernel/state_legacy_i32/" + name[len("kernel/state_u8/"):])
        if twin is None:
            failed.append(f"{name}: legacy_i32 twin missing from new run")
            continue
        for field in STATE_BYTES_FIELDS:
            u8_b, i32_b = row.get(field), twin.get(field)
            if not u8_b or not i32_b:
                failed.append(f"{name}: missing byte field {field}")
                continue
            reduction = i32_b / u8_b
            verdict = ("ok" if reduction >= STATE_BYTES_MIN_REDUCTION
                       else "FAIL")
            print(f"{name}: {field} reduction {reduction:.2f}x "
                  f"(min {STATE_BYTES_MIN_REDUCTION}x) {verdict}")
            if verdict == "FAIL":
                failed.append(
                    f"{name}: {field} reduced only {reduction:.2f}x")
    for name, r_base in sorted(base.items()):
        r_new = new.get(name)
        if r_new is None:
            failed.append(f"{name}: missing from new run")
            continue
        tol = args.tolerance
        for prefix, p_tol in PREFIX_TOLERANCE.items():
            if name.startswith(prefix):
                tol = p_tol
                # the hooks gate means "hooks add at most tol to the plain
                # row" — a baseline ratio < 1 is timer noise, and taking it
                # literally would shrink the limit below the claim
                r_base = max(r_base, 1.0)
        limit = r_base * (1.0 + tol)
        verdict = "FAIL" if r_new > limit else "ok"
        print(f"{name}: ratio {r_new:.3f} vs baseline {r_base:.3f} "
              f"(limit {limit:.3f}) {verdict}")
        if r_new > limit:
            failed.append(f"{name}: {r_new:.3f} > {limit:.3f}")
    if not base:
        print("no gated pipeline rows in baseline — nothing to check")
    if failed:
        print("\nregressions:\n  " + "\n  ".join(failed))
        return 1
    print("\nno gated pipeline regression beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
