"""Kernel micro-benchmarks.

Three matcher paths are timed, selectable with ``--matcher`` (``both`` runs
all of them):

* ``jnp``      — the single-device tiled matcher (``core.skipper``), the
                 windowed-oracle micro-bench, and the MoE b-matching router
                 (``kernel/bmatch/*``: tokens x experts sweep; accept-rate
                 and Medges/s recorded, gated in check_regression.py
                 normalized by the same-run ``window_match/tile128`` row).
* ``windowed`` — the device-resident window pipeline (``skipper_match``):
                 schedule precomputed once on the host, then the COMPILED
                 (non-interpret) pipeline is timed end-to-end. On CPU the
                 compiled path is the pipeline's XLA twin — identical
                 schedule and semantics, one compilation unit; on TPU the
                 same driver compiles the Pallas kernel via Mosaic. The host
                 precompute (including block-pair grouping, DESIGN.md §10)
                 is recorded per row as ``schedule_build_ms`` in the JSON —
                 visible in the trajectory but EXCLUDED from the Medges/s
                 cells, which time only the device pipeline. A
                 boundary-heavy pair of rows (``kernel/boundary_pipeline/*``
                 normalized by ``kernel/boundary_jnp/*``: rmat14, no
                 reorder, intra~0.13 — the global tier dominates) gates the
                 block-pair epilogue specifically, in smoke too.
* ``distributed`` — the multi-device matcher on 4 FORCED CPU host devices
                 (a subprocess sets ``--xla_force_host_platform_device_count``
                 so the main process keeps its jax). Two rows per graph:
                 ``kernel/distributed_pipeline/*`` (locality-sharded: the
                 window tier runs the device-resident pipeline per device,
                 only the global tier pays propose/gather/replay) and
                 ``kernel/distributed_jnp_local/*`` (the dispersed-block
                 jnp-local-pass baseline). The recorded JSON carries the
                 achieved ``intra`` fraction and collective payload
                 (``gathered_bytes``); check_regression.py gates the pipeline
                 row normalized by the jnp-local row of the same run.

A state-width A/B pair rides with the windowed rows (``kernel/state_u8/*``
vs ``kernel/state_legacy_i32/*``, interleaved min-of-N on the same
schedule): the u8 row runs the default single-byte ``StateSpec``, the twin
runs ``StateSpec.legacy_i32()`` (the exact pre-refactor i32 graph). The
recorded extras carry ``state_bytes_per_vertex`` and the analytic
VMEM/wire state payloads per spec; check_regression.py gates the u8 row's
throughput normalized by the legacy twin AND hard-fails if the byte
reduction drops below 3.5x.

``--reorder {none,degree,bfs,greedy}`` selects the locality renumbering the
windowed pipeline's schedule is built with (``graphs/reorder.py``; default
``degree``). The headline ``kernel/windowed_pipeline/*`` rows use it; a
``kernel/windowed_pipeline_noreorder/*`` row is always recorded next to them
so the trajectory captures the reorder win, and the recorded JSON carries the
achieved ``intra`` fraction and ``padding_waste`` per windowed row.

``--smoke`` runs a seconds-scale subset (CI); ``--record out.json`` writes
the rows as JSON so later PRs have a perf trajectory
(benchmarks/baseline_small.json / baseline_smoke.json are the committed
baselines; benchmarks/check_regression.py compares against them in CI).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bipartite import bmatch_assign
from repro.core.skipper import skipper
from repro.core.statespec import StateSpec
from repro.graphs import build_window_schedule, grid_graph, rmat_graph
from repro.kernels.skipper_match import skipper_match
from repro.kernels.skipper_match.ref import ref_match_window


def _bench_jnp(rows, extras, smoke: bool):
    """Windowed-oracle + MoE b-matching rows, measured INTERLEAVED
    (min-of-N round-robin, like _bench_windowed): check_regression gates the
    ``kernel/bmatch/*`` rows normalized by the same-run
    ``window_match/tile128`` row, and sequential medians let host-load drift
    between the two measurements poison the ratio (observed 2x)."""
    cells = []

    # windowed matcher throughput (edges/s) across tile sizes
    rng = np.random.default_rng(0)
    w, m = 2048, 1 << (13 if smoke else 16)
    u = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    v = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    st0 = jnp.zeros((w,), jnp.int32)
    for tile in (128,) if smoke else (128, 256, 512):
        ut = u.reshape(-1, tile)
        vt = v.reshape(-1, tile)
        cells.append((
            f"kernel/window_match/tile{tile}",
            lambda ut=ut, vt=vt: ref_match_window(ut, vt, st0)[1],
            lambda t, m=m: f"{m / t / 1e6:.1f}Medges_s",
            None,
        ))

    # MoE b-matching router (engine.tile_pass_capacitated): tokens x experts
    # sweep over a score-sorted candidate stream (gated, see docstring).
    cases = ((1024, 8, 2),) if smoke else ((4096, 8, 2), (4096, 40, 8))
    for n_tok, n_exp, k in cases:
        kp = min(n_exp, k + 2)
        scores = jax.random.normal(jax.random.PRNGKey(1), (n_tok, n_exp))
        vals, idx = jax.lax.top_k(scores, kp)
        tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kp)
        exp = idx.reshape(-1).astype(jnp.int32)
        order = jnp.argsort(-vals.reshape(-1))
        cap = int(n_tok * k / n_exp * 1.25)
        m_edges = n_tok * kp

        def assign(tok=tok, exp=exp, order=order, n_tok=n_tok, n_exp=n_exp,
                   k=k, cap=cap):
            return bmatch_assign(
                tok[order], exp[order], num_tokens=n_tok, num_experts=n_exp,
                token_budget=k, expert_capacity=cap,
            )

        accept_rate = float(jnp.mean(assign().astype(jnp.float32)))
        cells.append((
            f"kernel/bmatch/t{n_tok}_e{n_exp}_k{k}",
            assign,
            lambda t, m_edges=m_edges, a=accept_rate:
                f"{m_edges / t / 1e6:.1f}Medges_s_acc{a:.2f}",
            {"accept_rate": round(accept_rate, 4)},
        ))

    iters = 7
    times = {name: [] for name, _, _, _ in cells}
    for _ in range(iters + 1):  # first pass = warmup/compile
        for name, fn, _, _ in cells:
            times[name].append(time_call(fn, warmup=0, iters=1))
    for name, _, derived, extra in cells:
        t = min(times[name][1:])
        rows.append(emit(name, t, derived(t)))
        if extra is not None:
            extras[name] = extra


def _bench_windowed(rows, extras, scale: str, smoke: bool, reorder: str):
    """Compiled windowed-pipeline timings vs the jnp matcher, RMAT + grid."""
    if smoke:
        graphs = {"rmat12": rmat_graph(12, 8, seed=1), "grid_128": grid_graph(128, 128)}
        window, tile = 1024, 256
    elif scale == "large":
        graphs = {"rmat16": rmat_graph(16, 16, seed=1), "grid_1k": grid_graph(1024, 1024)}
        window, tile = 4096, 256
    else:
        graphs = {"rmat14": rmat_graph(14, 16, seed=1), "grid_256": grid_graph(256, 256)}
        window, tile = 2048, 256

    # On TPU the driver compiles the Pallas kernel via Mosaic; elsewhere the
    # compiled path is the pipeline's XLA twin (identical schedule/semantics).
    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    # min-of-9, INTERLEAVED: these rows gate the CI regression check
    # (check_regression.py) via the windowed/jnp ratio, and the shared
    # CI/dev hosts drift — measuring the cells round-robin makes every
    # cell's min sample the same wall-clock window, so the ratio stays
    # stable; the min itself estimates capability (noise is additive).
    iters = 9

    def _timed_schedule(g, **kw):
        t0 = time.perf_counter()
        s = build_window_schedule(g, window=window, tile_size=tile, **kw)
        return s, (time.perf_counter() - t0) * 1e3

    for name, g in graphs.items():
        m = g.num_edges
        # headline row: the requested reorder policy; plus the reorder-off
        # twin so the trajectory captures the locality win.
        cells = []
        sched, sched_ms = _timed_schedule(g, reorder=reorder)
        cells.append((f"kernel/windowed_pipeline/{name}", sched, sched_ms,
                      lambda s=sched: skipper_match(schedule=s, backend=backend)))
        if reorder != "none":
            off, off_ms = _timed_schedule(g)
            cells.append((f"kernel/windowed_pipeline_noreorder/{name}", off,
                          off_ms,
                          lambda s=off: skipper_match(schedule=s, backend=backend)))
        cells.append((f"kernel/jnp_matcher/{name}", None, None,
                      lambda: skipper(g, tile_size=tile)))

        times = {row_name: [] for row_name, _, _, _ in cells}
        for _ in range(iters + 1):  # first pass = warmup/compile
            for row_name, _, _, fn in cells:
                times[row_name].append(time_call(fn, warmup=0, iters=1))
        for row_name, sched_i, sched_ms_i, _ in cells:
            t = min(times[row_name][1:])
            if sched_i is None:
                rows.append(emit(row_name, t, f"{m / t / 1e6:.1f}Medges_s"))
                continue
            rows.append(emit(
                row_name, t,
                f"{m / t / 1e6:.1f}Medges_s_intra{sched_i.intra_fraction:.2f}"
                f"_pad{sched_i.padding_waste:.2f}",
            ))
            extras[row_name] = {
                "reorder": sched_i.reorder,
                "intra": round(sched_i.intra_fraction, 4),
                "windowed": round(sched_i.windowed_fraction, 4),
                "padding_waste": round(sched_i.padding_waste, 4),
                # host precompute, NOT in the Medges/s cell (device-only)
                "schedule_build_ms": round(sched_ms_i, 2),
            }


def _bench_statewidth(rows, extras, scale: str, smoke: bool, reorder: str):
    """State-width A/B on the full windowed pipeline: the default
    single-byte spec vs ``StateSpec.legacy_i32()`` on the SAME schedule,
    interleaved min-of-N. check_regression gates
    ``kernel/state_u8/<graph>`` normalized by the same-run legacy twin
    (>20% throughput regression fails) and hard-checks the recorded
    VMEM/wire state-byte reduction (>= 3.5x — the refactor's memory
    claim, DESIGN.md §12)."""
    if smoke:
        name, g = "rmat12", rmat_graph(12, 8, seed=1)
        window, tile = 1024, 256
    elif scale == "large":
        name, g = "rmat16", rmat_graph(16, 16, seed=1)
        window, tile = 4096, 256
    else:
        name, g = "rmat14", rmat_graph(14, 16, seed=1)
        window, tile = 2048, 256
    m = g.num_edges
    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    sched = build_window_schedule(g, window=window, tile_size=tile,
                                  reorder=reorder)

    specs = {
        f"kernel/state_u8/{name}": StateSpec.u8(),
        f"kernel/state_legacy_i32/{name}": StateSpec.legacy_i32(),
    }
    cells = [
        (cell, lambda s=spec: skipper_match(schedule=sched, backend=backend,
                                            spec=s))
        for cell, spec in specs.items()
    ]
    iters = 9
    times = {cell: [] for cell, _ in cells}
    for _ in range(iters + 1):  # first pass = warmup/compile
        for cell, fn in cells:
            times[cell].append(time_call(fn, warmup=0, iters=1))
    for cell, _ in cells:
        spec = specs[cell]
        t = min(times[cell][1:])
        rows.append(emit(
            cell, t,
            f"{m / t / 1e6:.1f}Medges_s_{spec.vmem_bytes}B_state",
        ))
        extras[cell] = {
            "reorder": sched.reorder,
            "state_bytes_per_vertex": spec.vmem_bytes,
            # analytic per-spec payloads of THIS schedule (windows.py):
            # the revolving VMEM block(s) and the D=4 PHASE A wire combine
            "vmem_state_bytes": sched.vmem_state_bytes(spec),
            "wire_state_bytes": sched.wire_state_bytes(spec, num_devices=4),
        }


def _bench_boundary(rows, extras):
    """Boundary-heavy gated pair (runs in smoke too): rmat14 with NO reorder
    leaves the global tier dominant (intra ~0.13), so
    ``kernel/boundary_pipeline/rmat14`` times the block-pair epilogue
    specifically; check_regression gates it normalized by the same-run
    ``kernel/boundary_jnp/rmat14`` tiled-matcher row (interleaved min-of-N,
    same protocol as the windowed cells)."""
    g = rmat_graph(14, 16, seed=1)
    m = g.num_edges
    tile = 256
    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    t0 = time.perf_counter()
    sched = build_window_schedule(g, window=2048, tile_size=tile)
    sched_ms = (time.perf_counter() - t0) * 1e3

    cells = [
        ("kernel/boundary_pipeline/rmat14",
         lambda: skipper_match(schedule=sched, backend=backend)),
        ("kernel/boundary_jnp/rmat14", lambda: skipper(g, tile_size=tile)),
    ]
    iters = 9
    times = {cell: [] for cell, _ in cells}
    for _ in range(iters + 1):  # first pass = warmup/compile
        for cell, fn in cells:
            times[cell].append(time_call(fn, warmup=0, iters=1))
    for cell, _ in cells:
        t = min(times[cell][1:])
        if cell.startswith("kernel/boundary_pipeline/"):
            rows.append(emit(
                cell, t,
                f"{m / t / 1e6:.1f}Medges_s_intra{sched.intra_fraction:.2f}",
            ))
            extras[cell] = {
                "reorder": sched.reorder,
                "intra": round(sched.intra_fraction, 4),
                "boundary_pairs": sched.num_boundary_pairs,
                "schedule_build_ms": round(sched_ms, 2),
            }
        else:
            rows.append(emit(cell, t, f"{m / t / 1e6:.1f}Medges_s"))


def _distributed_cases(scale: str, smoke: bool):
    """Graphs + schedule params for the distributed rows (subprocess side)."""
    if smoke:
        return {"rmat12": ("rmat", 12, 8, 1)}, 1024, 256, 512, 5
    if scale == "large":
        return {"rmat16": ("rmat", 16, 16, 1)}, 4096, 256, 512, 5
    return (
        {"rmat14": ("rmat", 14, 16, 1), "grid_256": ("grid", 256, 256, 0)},
        2048, 256, 512, 7,
    )


def _build_case(spec):
    kind, a, b, seed = spec
    return rmat_graph(a, b, seed=seed) if kind == "rmat" else grid_graph(a, b)


def distributed_worker(scale: str, smoke: bool, reorder: str) -> None:
    """Runs INSIDE the forced-4-device subprocess: times the locality-sharded
    distributed matcher against the dispersed jnp-local-pass baseline
    (interleaved min-of-N, like the windowed cells) and prints one JSON line
    with the rows + recorded extras."""
    import jax

    # the rows are recorded as 4-device CPU — pin exactly that (the forcing
    # flag is a no-op on accelerator backends)
    assert jax.default_backend() == "cpu", jax.default_backend()
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core.distributed import distributed_skipper
    from repro.core.faults import FaultPlan
    from repro.graphs import partition_schedule

    # Active-but-inert plan: truncate_retry far above any retry capacity, so
    # the compiled work is identical to the plain row — the cell times what
    # the fault-harness plumbing itself costs (threading a plan through the
    # compile cache + the policy epilogue). check_regression gates this
    # against the plain pipeline row at 2%.
    inert = FaultPlan(seed=0, truncate_retry=1 << 30)

    specs, window, tile, block, iters = _distributed_cases(scale, smoke)
    rows, extras = [], {}
    for name, spec in specs.items():
        g = _build_case(spec)
        m = g.num_edges
        sched = build_window_schedule(g, window=window, tile_size=tile,
                                      reorder=reorder)
        ds = partition_schedule(sched, 4, block)
        last = {}  # the timed calls' stats — no extra stat-collection runs

        def keep(cell, out):
            last[cell] = out[1]
            return out

        cells = [
            (f"kernel/distributed_pipeline/{name}",
             lambda ds=ds, c=f"kernel/distributed_pipeline/{name}": keep(
                 c, distributed_skipper(device_schedule=ds, tile_size=tile))),
            (f"kernel/distributed_pipeline_hooks/{name}",
             lambda ds=ds, c=f"kernel/distributed_pipeline_hooks/{name}": keep(
                 c, distributed_skipper(device_schedule=ds, tile_size=tile,
                                        faults=inert))),
            (f"kernel/distributed_jnp_local/{name}",
             lambda g=g, c=f"kernel/distributed_jnp_local/{name}": keep(
                 c, distributed_skipper(g, block_size=block, tile_size=tile))),
        ]
        times = {cell: [] for cell, _ in cells}
        for _ in range(iters + 1):  # first pass = warmup/compile
            for cell, fn in cells:
                times[cell].append(time_call(fn, warmup=0, iters=1))
        # one NON-timed verified run: a fault-free bench must report zero on
        # every recovery field (check_regression hard-fails otherwise —
        # nonzero here means the matcher silently dropped work)
        _, vstats = distributed_skipper(
            g, device_schedule=ds, tile_size=tile,
            on_fault="report", verify=True,
        )
        recovery = {
            k: int(getattr(vstats, k)) for k in (
                "recovery_attempts", "residual_edges",
                "recovered_matches", "corrupted_cells",
            )
        }
        for cell, _ in cells:
            t = min(times[cell][1:])
            gbytes = int(last[cell].gathered_bytes)
            if cell.startswith("kernel/distributed_pipeline/"):
                derived = (f"{m / t / 1e6:.1f}Medges_s"
                           f"_intra{sched.intra_fraction:.2f}")
                extras[cell] = {
                    "reorder": sched.reorder,
                    "intra": round(sched.intra_fraction, 4),
                    "gathered_bytes": gbytes,
                    "num_devices": 4,
                    **recovery,
                }
            else:
                derived = f"{m / t / 1e6:.1f}Medges_s"
                extras[cell] = {
                    "gathered_bytes": gbytes,
                    "num_devices": 4,
                }
            rows.append(f"{cell},{t * 1e6:.1f},{derived}")
    print(json.dumps({"rows": rows, "extras": extras}))


def _bench_distributed(rows, extras, scale: str, smoke: bool, reorder: str):
    """Spawn the forced-4-device subprocess and merge its rows/extras."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p
    )
    cmd = [sys.executable, "-m", "benchmarks.kernel_bench",
           "--distributed-worker", "--scale", scale, "--reorder", reorder]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"distributed bench worker failed:\n{proc.stderr[-3000:]}"
        )
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    for line in payload["rows"]:
        print(line, flush=True)
        rows.append(line)
    extras.update(payload["extras"])


def run(scale: str = "small", matcher: str = "both", smoke: bool = False,
        record: str | None = None, reorder: str = "degree"):
    rows = []
    extras = {}
    if matcher in ("both", "jnp"):
        _bench_jnp(rows, extras, smoke)
    if matcher in ("both", "windowed"):
        _bench_windowed(rows, extras, scale, smoke, reorder)
        _bench_statewidth(rows, extras, scale, smoke, reorder)
        _bench_boundary(rows, extras)
    if matcher in ("both", "distributed"):
        _bench_distributed(rows, extras, scale, smoke, reorder)
    if record:
        data = {}
        for line in rows:
            name, us, derived = line.split(",", 2)
            data[name] = {"us_per_call": float(us), "derived": derived}
            data[name].update(extras.get(name, {}))
        with open(record, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "large"])
    ap.add_argument("--matcher", default="both",
                    choices=["both", "jnp", "windowed", "distributed"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", default=None)
    ap.add_argument("--reorder", default="degree",
                    choices=["none", "degree", "bfs", "greedy"])
    ap.add_argument("--distributed-worker", action="store_true",
                    help="internal: run the forced-4-device timing body and "
                         "emit one JSON line (spawned by _bench_distributed)")
    args = ap.parse_args()
    if args.distributed_worker:
        distributed_worker(args.scale, args.smoke, args.reorder)
    else:
        print("name,us_per_call,derived")
        run(args.scale, matcher=args.matcher, smoke=args.smoke,
            record=args.record, reorder=args.reorder)
