"""Kernel micro-benchmarks: the Pallas matcher's pure-jnp twin (the kernel
itself runs in interpret mode on CPU — timing it would measure the Python
interpreter, so we time the algorithmically identical ref path and the
MoE matching router which is the technique's in-framework hot spot)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bipartite import bmatch_assign
from repro.kernels.skipper_match.ref import ref_match_window


def run(scale: str = "small"):
    rows = []
    # windowed matcher throughput (edges/s) across tile sizes
    rng = np.random.default_rng(0)
    w, m = 2048, 1 << 16
    u = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    v = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    st0 = jnp.zeros((w,), jnp.int32)
    for tile in (128, 256, 512):
        ut = u.reshape(-1, tile)
        vt = v.reshape(-1, tile)
        t = time_call(lambda: ref_match_window(ut, vt, st0)[1])
        rows.append(emit(f"kernel/window_match/tile{tile}", t,
                         f"{m / t / 1e6:.1f}Medges_s"))

    # MoE matching router: tokens x experts
    for n_tok, n_exp, k in ((4096, 8, 2), (4096, 40, 8)):
        kp = min(n_exp, k + 2)
        scores = jax.random.normal(jax.random.PRNGKey(1), (n_tok, n_exp))
        vals, idx = jax.lax.top_k(scores, kp)
        tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kp)
        exp = idx.reshape(-1).astype(jnp.int32)
        order = jnp.argsort(-vals.reshape(-1))
        cap = int(n_tok * k / n_exp * 1.25)

        def assign():
            return bmatch_assign(
                tok[order], exp[order], num_tokens=n_tok, num_experts=n_exp,
                token_budget=k, expert_capacity=cap,
            )

        t = time_call(assign)
        rows.append(emit(f"kernel/moe_router/t{n_tok}_e{n_exp}_k{k}", t,
                         f"{n_tok / t / 1e6:.2f}Mtok_s"))
    return rows


if __name__ == "__main__":
    run()
