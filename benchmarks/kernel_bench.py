"""Kernel micro-benchmarks.

Two matcher paths are timed, selectable with ``--matcher``:

* ``jnp``      — the single-device tiled matcher (``core.skipper``) and the
                 windowed oracle / MoE router micro-benches.
* ``windowed`` — the device-resident window pipeline (``skipper_match``):
                 schedule precomputed once on the host, then the COMPILED
                 (non-interpret) pipeline is timed end-to-end. On CPU the
                 compiled path is the pipeline's XLA twin — identical
                 schedule and semantics, one compilation unit; on TPU the
                 same driver compiles the Pallas kernel via Mosaic.

``--reorder {none,degree,bfs,greedy}`` selects the locality renumbering the
windowed pipeline's schedule is built with (``graphs/reorder.py``; default
``degree``). The headline ``kernel/windowed_pipeline/*`` rows use it; a
``kernel/windowed_pipeline_noreorder/*`` row is always recorded next to them
so the trajectory captures the reorder win, and the recorded JSON carries the
achieved ``intra`` fraction and ``padding_waste`` per windowed row.

``--smoke`` runs a seconds-scale subset (CI); ``--record out.json`` writes
the rows as JSON so later PRs have a perf trajectory
(benchmarks/baseline_small.json / baseline_smoke.json are the committed
baselines; benchmarks/check_regression.py compares against them in CI).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bipartite import bmatch_assign
from repro.core.skipper import skipper
from repro.graphs import build_window_schedule, grid_graph, rmat_graph
from repro.kernels.skipper_match import skipper_match
from repro.kernels.skipper_match.ref import ref_match_window


def _bench_jnp(rows, smoke: bool):
    # windowed matcher throughput (edges/s) across tile sizes
    rng = np.random.default_rng(0)
    w, m = 2048, 1 << (13 if smoke else 16)
    u = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    v = jnp.asarray(rng.integers(0, w, m), jnp.int32)
    st0 = jnp.zeros((w,), jnp.int32)
    for tile in (128,) if smoke else (128, 256, 512):
        ut = u.reshape(-1, tile)
        vt = v.reshape(-1, tile)
        t = time_call(lambda: ref_match_window(ut, vt, st0)[1])
        rows.append(emit(f"kernel/window_match/tile{tile}", t,
                         f"{m / t / 1e6:.1f}Medges_s"))

    # MoE matching router: tokens x experts
    cases = ((1024, 8, 2),) if smoke else ((4096, 8, 2), (4096, 40, 8))
    for n_tok, n_exp, k in cases:
        kp = min(n_exp, k + 2)
        scores = jax.random.normal(jax.random.PRNGKey(1), (n_tok, n_exp))
        vals, idx = jax.lax.top_k(scores, kp)
        tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kp)
        exp = idx.reshape(-1).astype(jnp.int32)
        order = jnp.argsort(-vals.reshape(-1))
        cap = int(n_tok * k / n_exp * 1.25)

        def assign():
            return bmatch_assign(
                tok[order], exp[order], num_tokens=n_tok, num_experts=n_exp,
                token_budget=k, expert_capacity=cap,
            )

        t = time_call(assign)
        rows.append(emit(f"kernel/moe_router/t{n_tok}_e{n_exp}_k{k}", t,
                         f"{n_tok / t / 1e6:.2f}Mtok_s"))


def _bench_windowed(rows, extras, scale: str, smoke: bool, reorder: str):
    """Compiled windowed-pipeline timings vs the jnp matcher, RMAT + grid."""
    if smoke:
        graphs = {"rmat12": rmat_graph(12, 8, seed=1), "grid_128": grid_graph(128, 128)}
        window, tile = 1024, 256
    elif scale == "large":
        graphs = {"rmat16": rmat_graph(16, 16, seed=1), "grid_1k": grid_graph(1024, 1024)}
        window, tile = 4096, 256
    else:
        graphs = {"rmat14": rmat_graph(14, 16, seed=1), "grid_256": grid_graph(256, 256)}
        window, tile = 2048, 256

    # On TPU the driver compiles the Pallas kernel via Mosaic; elsewhere the
    # compiled path is the pipeline's XLA twin (identical schedule/semantics).
    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    # min-of-9, INTERLEAVED: these rows gate the CI regression check
    # (check_regression.py) via the windowed/jnp ratio, and the shared
    # CI/dev hosts drift — measuring the cells round-robin makes every
    # cell's min sample the same wall-clock window, so the ratio stays
    # stable; the min itself estimates capability (noise is additive).
    iters = 9

    for name, g in graphs.items():
        m = g.num_edges
        # headline row: the requested reorder policy; plus the reorder-off
        # twin so the trajectory captures the locality win.
        cells = []
        sched = build_window_schedule(g, window=window, tile_size=tile,
                                      reorder=reorder)
        cells.append((f"kernel/windowed_pipeline/{name}", sched,
                      lambda s=sched: skipper_match(schedule=s, backend=backend)))
        if reorder != "none":
            off = build_window_schedule(g, window=window, tile_size=tile)
            cells.append((f"kernel/windowed_pipeline_noreorder/{name}", off,
                          lambda s=off: skipper_match(schedule=s, backend=backend)))
        cells.append((f"kernel/jnp_matcher/{name}", None,
                      lambda: skipper(g, tile_size=tile)))

        times = {row_name: [] for row_name, _, _ in cells}
        for _ in range(iters + 1):  # first pass = warmup/compile
            for row_name, _, fn in cells:
                times[row_name].append(time_call(fn, warmup=0, iters=1))
        for row_name, sched_i, _ in cells:
            t = min(times[row_name][1:])
            if sched_i is None:
                rows.append(emit(row_name, t, f"{m / t / 1e6:.1f}Medges_s"))
                continue
            rows.append(emit(
                row_name, t,
                f"{m / t / 1e6:.1f}Medges_s_intra{sched_i.intra_fraction:.2f}"
                f"_pad{sched_i.padding_waste:.2f}",
            ))
            extras[row_name] = {
                "reorder": sched_i.reorder,
                "intra": round(sched_i.intra_fraction, 4),
                "windowed": round(sched_i.windowed_fraction, 4),
                "padding_waste": round(sched_i.padding_waste, 4),
            }


def run(scale: str = "small", matcher: str = "both", smoke: bool = False,
        record: str | None = None, reorder: str = "degree"):
    rows = []
    extras = {}
    if matcher in ("both", "jnp"):
        _bench_jnp(rows, smoke)
    if matcher in ("both", "windowed"):
        _bench_windowed(rows, extras, scale, smoke, reorder)
    if record:
        data = {}
        for line in rows:
            name, us, derived = line.split(",", 2)
            data[name] = {"us_per_call": float(us), "derived": derived}
            data[name].update(extras.get(name, {}))
        with open(record, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "large"])
    ap.add_argument("--matcher", default="both", choices=["both", "jnp", "windowed"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", default=None)
    ap.add_argument("--reorder", default="degree",
                    choices=["none", "degree", "bfs", "greedy"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.scale, matcher=args.matcher, smoke=args.smoke,
        record=args.record, reorder=args.reorder)
