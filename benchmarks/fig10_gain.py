"""Fig. 10/11 analogue: Parallelization Gain and Serial Slowdown.

The paper defines gain = t_sequential / t_parallel and slowdown =
t_parallel_1thread / t_sequential. On this 1-core container wall-clock
parallel gain is not measurable, so we report the two *work-side* components
the paper identifies as its drivers (§VI-D): excess memory accesses
(slowdown proxy — Skipper ~1.4x vs SIDMM ~10.7x in the paper) plus the
single-thread wall-time ratio of each parallel algorithm against SGMM, which
IS the paper's Serial Slowdown (Fig. 11), measurable here exactly.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import graph_suite, time_call, emit
from repro.core import sgmm, skipper, sidmm


def run(scale: str = "small"):
    rows = []
    slow_skip, slow_sidmm = [], []
    for name, g in graph_suite(scale).items():
        t_sgmm = time_call(lambda: sgmm(g).match_mask)
        t_skip = time_call(lambda: skipper(g, tile_size=32, vector_rounds=1)[0].match_mask)
        t_sidmm = time_call(lambda: sidmm(g, batch_size=4096).match_mask)
        s1 = t_skip / t_sgmm
        s2 = t_sidmm / t_sgmm
        slow_skip.append(s1)
        slow_sidmm.append(s2)
        rows.append(emit(f"fig11/{name}/skipper_serial_slowdown", t_skip, f"{s1:.2f}x"))
        rows.append(emit(f"fig11/{name}/sidmm_serial_slowdown", t_sidmm, f"{s2:.2f}x"))
    rows.append(emit("fig11/geomean/skipper", 0.0,
                     f"{float(np.exp(np.mean(np.log(slow_skip)))):.2f}x"))
    rows.append(emit("fig11/geomean/sidmm", 0.0,
                     f"{float(np.exp(np.mean(np.log(slow_sidmm)))):.2f}x"))
    return rows


if __name__ == "__main__":
    run()
