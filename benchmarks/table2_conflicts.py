"""Table II analogue: JIT conflict statistics.

The paper: conflict ratio < 0.1% of edges on every dataset; max conflicts per
edge 410; most conflicting edges see < 16 conflicts. We report the identical
statistics from the tiled matcher's blocked-edge instrumentation, plus the
cross-device conflicts (lost proposals / requeues) of the distributed run.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import graph_suite, emit
from repro.core import skipper, conflict_table
from repro.core.distributed import distributed_skipper


def run(scale: str = "small"):
    rows = []
    for name, g in graph_suite(scale).items():
        _, conf = skipper(g, tile_size=32, vector_rounds=1, with_conflicts=True)
        tbl = conflict_table(np.asarray(conf))
        rows.append(emit(
            f"table2/{name}", 0.0,
            f"total={tbl['total_cnf']};edges={tbl['edges_exp_cnf']};"
            f"max={tbl['max_cnf_per_edge']};avg={tbl['avg_cnf_per_edge']:.1f};"
            f"ratio={tbl['conflict_ratio']:.5f};dist={tbl['distribution']}"
        ))
        _, st = distributed_skipper(g, block_size=512)
        rows.append(emit(
            f"table2/{name}/distributed", 0.0,
            f"proposals={int(st.proposals)};lost={int(st.lost_proposals)};"
            f"requeued={int(st.requeued)};overflow={int(st.retry_overflow)}"
        ))
    return rows


if __name__ == "__main__":
    run()
