"""Table I analogue: Skipper vs SIDMM execution time (+SGMM reference).

The paper reports 4.9-15.6x (geomean 8.0x) over SIDMM on 64 threads with
2.4G-224G-edge graphs; here both algorithms are jit-compiled XLA:CPU programs
over laptop-scale graphs of the same families. The measured quantity is the
same: end-to-end matching time after the topology is in memory.

Tile size: the JIT-conflict mask is O(T^2) per T-edge tile, i.e. O(T) per
edge — lanes on a TPU VPU, real scalar work on 1-core CPU. Benchmarks use
the CPU-optimal (tile=32, rounds=1); the library default (512) is the
MXU/VPU-aligned choice (EXPERIMENTS §Perf iteration 12).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import graph_suite, time_call, emit
from repro.core import sgmm, skipper, sidmm, assert_matching


def run(scale: str = "small"):
    rows = []
    speedups = []
    for name, g in graph_suite(scale).items():
        t_skip = time_call(lambda: skipper(g, tile_size=32, vector_rounds=1)[0].match_mask)
        t_sidmm = time_call(lambda: sidmm(g, batch_size=4096).match_mask)
        t_sgmm = time_call(lambda: sgmm(g).match_mask)
        assert_matching(g, skipper(g, tile_size=32, vector_rounds=1)[0].match_mask, name)
        sp = t_sidmm / t_skip
        speedups.append(sp)
        rows.append(emit(f"table1/{name}/skipper", t_skip, f"|E|={g.num_edges}"))
        rows.append(emit(f"table1/{name}/sidmm", t_sidmm, f"speedup={sp:.2f}x"))
        rows.append(emit(f"table1/{name}/sgmm_1t", t_sgmm, "sequential_reference"))
    geo = float(np.exp(np.mean(np.log(speedups))))
    rows.append(emit("table1/geomean_speedup_vs_sidmm", 0.0, f"{geo:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
