"""Shared benchmark harness: timed jit calls + the paper's graph suite at
laptop scale.

The paper's datasets (2.4G-224G edges) are replaced by same-family synthetic
graphs sized for this container; every benchmark prints CSV
``name,us_per_call,derived`` so benchmarks.run can aggregate.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

from repro.graphs import (
    EdgeList, erdos_renyi_graph, grid_graph, rmat_graph,
)


def graph_suite(scale: str = "small") -> Dict[str, EdgeList]:
    """Graph families mirroring the paper's Table I categories:
    social/synthetic (RMAT skew), web-like (high locality grid+er mix),
    uniform random."""
    if scale == "large":
        return {
            "rmat18": rmat_graph(18, 16, seed=1),          # ~4.2M edges
            "er_4m": erdos_renyi_graph(2**18, 2**22, seed=2),
            "grid_1k": grid_graph(1024, 1024),             # ~2.1M edges, high locality
        }
    return {
        "rmat14": rmat_graph(14, 16, seed=1),              # ~262k edges
        "er_256k": erdos_renyi_graph(2**14, 2**18, seed=2),
        "grid_256": grid_graph(256, 256),                  # ~131k edges
    }


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (seconds) of a jit'd call, post-warmup. (The
    regression-gated windowed rows don't use this reduction: kernel_bench
    interleaves its cells and takes per-cell minima — see _bench_windowed.)"""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line
