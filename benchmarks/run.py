"""Benchmark aggregator: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).

  table1_speedup    — Table I: Skipper vs SIDMM wall time (+SGMM ref)
  fig7_work         — Fig. 7: memory accesses per edge
  fig10_gain        — Fig. 10/11: serial slowdown vs SGMM
  table2_conflicts  — Table II: JIT conflict statistics (+distributed)
  kernel_bench      — matcher/router throughput micro-benches
  packing_bench     — matching-based sequence packing quality

Run ``--scale large`` for the multi-million-edge suite (slower).
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "large"])
    ap.add_argument("--only", default=None)
    ap.add_argument("--matcher", default="both",
                    choices=["both", "jnp", "windowed", "distributed"],
                    help="which matcher path kernel_bench times (jnp tiled, "
                         "device-resident windowed pipeline, or the "
                         "forced-4-device distributed matcher)")
    ap.add_argument("--reorder", default="degree",
                    choices=["none", "degree", "bfs", "greedy"],
                    help="locality reordering for the windowed schedule")
    args = ap.parse_args()

    from benchmarks import (
        table1_speedup, fig7_work, fig10_gain, table2_conflicts,
        kernel_bench, packing_bench,
    )

    modules = {
        "table1": table1_speedup,
        "fig7": fig7_work,
        "fig10": fig10_gain,
        "table2": table2_conflicts,
        "kernels": kernel_bench,
        "packing": packing_bench,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        try:
            if name == "kernels":
                mod.run(args.scale, matcher=args.matcher, reorder=args.reorder)
            else:
                mod.run(args.scale)
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
