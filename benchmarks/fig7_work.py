"""Fig. 7 analogue: memory accesses per edge (work efficiency).

The paper: SGMM 0.3-0.8, Skipper 1.2-3.4 (geomean 2.1), SIDMM 16.7-26.9
(geomean 21.0). Our counters instrument the same quantity — state-array
loads/stores + topology reads — inside each algorithm.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import graph_suite, emit
from repro.core import sgmm, skipper, sidmm, ems_israeli_itai
from repro.core.distributed import distributed_skipper


def run(scale: str = "small"):
    rows = []
    ratios = {"skipper": [], "sidmm": []}
    for name, g in graph_suite(scale).items():
        m = g.num_edges
        for algo, fn in [
            ("sgmm", lambda: sgmm(g)),
            ("skipper", lambda: skipper(g, tile_size=32, vector_rounds=1)[0]),
            ("sidmm", lambda: sidmm(g, batch_size=4096)),
            ("ems_ii", lambda: ems_israeli_itai(g)),
            # distributed counters use the same real-edge-work accounting
            # (sentinel slots scanned during drain rounds count nothing),
            # so this row is directly comparable to skipper's
            ("skipper_dist", lambda: distributed_skipper(g, block_size=4096)[0]),
        ]:
            r = fn()
            per_edge = float(r.counters.total_accesses) / m
            rounds = int(r.counters.rounds)
            if algo in ratios:
                ratios[algo].append(per_edge)
            rows.append(
                emit(f"fig7/{name}/{algo}", 0.0,
                     f"accesses_per_edge={per_edge:.2f};rounds={rounds}")
            )
    for algo, vals in ratios.items():
        geo = float(np.exp(np.mean(np.log(vals))))
        rows.append(emit(f"fig7/geomean/{algo}", 0.0, f"accesses_per_edge={geo:.2f}"))
    return rows


if __name__ == "__main__":
    run()
