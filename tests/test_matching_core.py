"""Correctness of the paper's core: Skipper + baselines.

Output validation follows the paper §II-B: (a) no two selected edges share an
endpoint; (b) every edge has a selected endpoint (maximality). Hypothesis
drives random graph instances at the system-invariant level.
"""
import numpy as np
import pytest

from strategies import given, random_edge_list, settings, st  # noqa: E402

from repro.core import (
    sgmm, skipper, ems_israeli_itai, ems_idmm, sidmm,
    check_matching, assert_matching, conflict_table,
)
from repro.graphs import (
    EdgeList, rmat_graph, erdos_renyi_graph, grid_graph, star_graph,
    path_graph, ring_graph,
)

GRAPHS = {
    "path": lambda: path_graph(257),
    "ring": lambda: ring_graph(100),
    "star": lambda: star_graph(100),
    "grid": lambda: grid_graph(24, 24),
    "er": lambda: erdos_renyi_graph(2000, 8000, seed=1),
    "rmat": lambda: rmat_graph(10, 8, seed=2),
}

ALGOS = {
    "sgmm": lambda g: sgmm(g),
    "skipper": lambda g: skipper(g, tile_size=128)[0],
    "ems_ii": lambda g: ems_israeli_itai(g),
    "ems_idmm": lambda g: ems_idmm(g),
    "sidmm": lambda g: sidmm(g, batch_size=512),
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("aname", sorted(ALGOS))
def test_valid_and_maximal(gname, aname):
    g = GRAPHS[gname]()
    result = ALGOS[aname](g)
    assert_matching(g, result.match_mask, f"{aname}/{gname}")


def test_matching_sizes_comparable():
    """All maximal matchings are within 2x of each other (classic bound:
    any maximal matching is a 1/2-approximation of maximum)."""
    g = erdos_renyi_graph(3000, 12000, seed=3)
    sizes = {name: int(fn(g).num_matches) for name, fn in ALGOS.items()}
    lo, hi = min(sizes.values()), max(sizes.values())
    assert hi <= 2 * lo, sizes


def test_skipper_single_pass_work_efficiency():
    """Fig. 7 analogue: Skipper's state accesses per edge stay in the paper's
    1.2-3.4 band on realistic graphs; SIDMM pays an order of magnitude more."""
    g = rmat_graph(12, 16, seed=4)
    r_skip = skipper(g, tile_size=256)[0]
    r_sidmm = sidmm(g, batch_size=2048)
    per_edge_skip = float(r_skip.counters.total_accesses) / g.num_edges
    per_edge_sidmm = float(r_sidmm.counters.total_accesses) / g.num_edges
    assert per_edge_skip < 4.5, per_edge_skip
    assert per_edge_sidmm > 2 * per_edge_skip, (per_edge_skip, per_edge_sidmm)


def test_skipper_rounds_is_one():
    g = erdos_renyi_graph(1000, 4000, seed=5)
    assert int(skipper(g)[0].counters.rounds) == 1
    assert int(sidmm(g, batch_size=512).counters.rounds) > 1


def test_dispersed_scheduler_reduces_conflicts():
    """§IV-C/V-B: thread-dispersed locality-preserving scheduling makes JIT
    conflicts rare on high-locality graphs."""
    g = grid_graph(40, 40)
    _, c_disp = skipper(g, tile_size=256, with_conflicts=True, dispersed=True)
    _, c_cont = skipper(g, tile_size=256, with_conflicts=True, dispersed=False)
    assert int(np.asarray(c_disp).sum()) < int(np.asarray(c_cont).sum()) / 3


def test_conflicts_rare_on_random_graphs():
    """Table II analogue: conflict ratio << 1% on randomized inputs."""
    g = erdos_renyi_graph(20000, 100000, seed=6)
    _, conf = skipper(g, tile_size=256, with_conflicts=True)
    tbl = conflict_table(np.asarray(conf))
    assert tbl["conflict_ratio"] < 0.01, tbl


def test_conflict_table_buckets():
    c = np.array([0, 1, 1, 2, 5, 17, 300])
    tbl = conflict_table(c)
    assert tbl["total_cnf"] == 326
    assert tbl["edges_exp_cnf"] == 6
    assert tbl["max_cnf_per_edge"] == 300
    assert tbl["distribution"][0] == 2      # ones
    assert tbl["distribution"][-1] == 1     # >256


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 120),
    m=st.integers(1, 400),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([32, 64, 128]),
    dispersed=st.booleans(),
)
def test_property_skipper_valid_maximal(n, m, seed, tile, dispersed):
    g = random_edge_list(seed, n, m)
    res, _ = skipper(g, tile_size=tile, dispersed=dispersed)
    out = check_matching(g, res.match_mask)
    assert bool(out["valid"]) and bool(out["maximal"])


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 80),
    m=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_all_algorithms_agree_on_coverage(n, m, seed):
    """Invariant: the set of covered vertices differs between algorithms, but
    every algorithm's output is a valid maximal matching of the same graph."""
    g = random_edge_list(seed, n, m)
    for name, fn in ALGOS.items():
        out = check_matching(g, fn(g).match_mask)
        assert bool(out["valid"]) and bool(out["maximal"]), name


# ---------------------------------------------------------------------------
# edge-order adversaries: stream_pass vs the sequential-greedy oracle on
# hazardous streams (hubs, duplicate slots, self-loops). stream_pass's
# fixpoint IS index-order greedy — these pin it on exactly the stream
# shapes where a reservation-order bug would diverge (ISSUE 9 satellite).
# ---------------------------------------------------------------------------
def _stream_pass_mask(g, tile_size=32):
    import jax.numpy as jnp
    from repro.core import engine
    from repro.core.types import ACC, STATE_DTYPE

    e = g.canonical()
    m = e.num_edges
    pad = (-m) % tile_size
    u = jnp.concatenate([e.u, jnp.full((pad,), -1, jnp.int32)])
    v = jnp.concatenate([e.v, jnp.full((pad,), -1, jnp.int32)])
    state = jnp.full((g.num_vertices,), ACC, STATE_DTYPE)
    _, matched, _ = engine.stream_pass(
        state, u, v, n=g.num_vertices, vector_rounds=1, tile_size=tile_size
    )
    return np.asarray(matched)[:m]


def _hazard_streams():
    import jax.numpy as jnp

    def star_with_hazards(seed):
        # hub 0 fanning out, every hub edge duplicated, self-loops on the
        # hub and leaves, plus a tail of leaf-leaf edges for contention
        rng = np.random.default_rng(seed)
        leaves = rng.permutation(np.arange(1, 40))
        u = [0] * len(leaves) + [0] * len(leaves) + [0, 5, 17]
        v = list(leaves) + list(leaves) + [0, 5, 17]
        lu = rng.integers(1, 40, 30)
        lv = rng.integers(1, 40, 30)
        u += list(lu)
        v += list(lv)
        return EdgeList(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32), 40)

    def double_star(seed):
        # two hubs sharing leaves: order of hub edges decides everything
        rng = np.random.default_rng(seed)
        m = 60
        hub = rng.integers(0, 2, m)
        leaf = rng.integers(2, 30, m)
        return EdgeList(jnp.asarray(hub, jnp.int32),
                        jnp.asarray(leaf, jnp.int32), 30)

    return {
        "star_hazards": star_with_hazards,
        "double_star": double_star,
    }


@pytest.mark.parametrize("sname", sorted(_hazard_streams()))
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("tile", [8, 32])
def test_stream_pass_matches_sequential_greedy_on_hazards(sname, seed, tile):
    g = _hazard_streams()[sname](seed)
    got = _stream_pass_mask(g, tile_size=tile)
    want = np.asarray(sgmm(g).match_mask)
    np.testing.assert_array_equal(got, want)
    assert_matching(g, sgmm(g).match_mask, f"hazard/{sname}")


def test_stream_pass_self_loops_and_duplicates_never_match_twice():
    import jax.numpy as jnp
    u = jnp.asarray([3, 3, 3, 1, 1, -1], jnp.int32)
    v = jnp.asarray([3, 4, 4, 2, 2, 5], jnp.int32)
    g = EdgeList(u, v, 6)
    got = _stream_pass_mask(g, tile_size=2)
    # self-loop dead; first (3,4) wins; its duplicate dead; first (1,2)
    # wins; its duplicate dead; invalid slot dead
    np.testing.assert_array_equal(got, [False, True, False, True, False, False])


# ---------------------------------------------------------------------------
# assert_matching failure diagnostics (ISSUE 9 satellite): the message names
# the first offending edge (u, v, stream index), not just a bare count.
# ---------------------------------------------------------------------------
def test_assert_matching_reports_first_collision_edge():
    import jax.numpy as jnp
    g = EdgeList(jnp.asarray([0, 1, 2], jnp.int32),
                 jnp.asarray([1, 2, 3], jnp.int32), 4)
    bad = jnp.asarray([True, True, False])  # (1,2) reuses vertex 1
    with pytest.raises(AssertionError) as exc:
        assert_matching(g, bad, "unit")
    msg = str(exc.value)
    assert "unit: matching has endpoint collisions" in msg
    assert "(1, 2)" in msg and "stream index 1" in msg


def test_assert_matching_reports_first_uncovered_edge():
    import jax.numpy as jnp
    g = EdgeList(jnp.asarray([0, 2], jnp.int32),
                 jnp.asarray([1, 3], jnp.int32), 4)
    bad = jnp.asarray([True, False])  # (2,3) left free
    with pytest.raises(AssertionError) as exc:
        assert_matching(g, bad, "unit")
    msg = str(exc.value)
    assert "unit: matching is not maximal" in msg
    assert "(2, 3)" in msg and "stream index 1" in msg
