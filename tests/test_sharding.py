"""Sharding rules: every param gets a legal spec; divisibility fallback."""
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import adapters
from repro.parallel.sharding import param_specs, rules_for_mesh


def fake_mesh(shape=(4, 2), names=("data", "model")):
    return compat.abstract_mesh(shape, names)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_cover_all_params(arch):
    cfg = get_smoke_config(arch)
    tree = jax.eval_shape(lambda: adapters.init_fn(jax.random.PRNGKey(0), cfg))
    mesh = fake_mesh((1, 1))
    specs = param_specs(tree, mesh)
    n_leaves = len(jax.tree.leaves(tree))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert n_specs == n_leaves


def test_divisibility_fallback():
    """A dim that doesn't divide the axis size must be replicated, not error."""
    mesh = fake_mesh((4, 2))
    tree = {"wq": jax.ShapeDtypeStruct((6, 10), jnp.float32)}  # 6 % 4 != 0
    spec = jax.tree.leaves(
        param_specs(tree, mesh),
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )[0]
    assert spec[0] is None          # fsdp dim replicated
    assert spec[1] == "model"       # tp dim sharded (10 % 2 == 0)


def test_big_model_params_sharded():
    """llama3-405b under the production mesh: the big matrices must be
    2-D sharded (fsdp x tp) or the model cannot fit."""
    cfg = get_config("llama3-405b")
    tree = jax.eval_shape(lambda: adapters.init_fn(jax.random.PRNGKey(0), cfg))
    mesh = fake_mesh((4, 2))
    specs = param_specs(tree, mesh)
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert wq_spec[1] is not None and wq_spec[2] is not None


def test_rules_pod_axes():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = rules_for_mesh(mesh)
    assert rules.fsdp == ("pod", "data")
    assert rules.tp == "model"
