"""The capacitated first-K-claim engine (DESIGN.md §9) and the MoE b-matching
router built on it.

Pins the three contracts the PR-4 unification relies on:
  * bmatch_assign == the sequential greedy over the score-sorted stream
    (exact, not just valid-and-maximal);
  * the three per-side rank implementations compute the identical function;
  * at unit capacity the capacitated path is bit-identical to the engine's
    unit-capacity first-claim rounds (the paper's reservation step).
"""
import numpy as np
import jax.numpy as jnp

# property tests need hypothesis (a [dev] dep); the deterministic pins don't
from strategies import given, settings, st  # noqa: E402

from repro.core import engine
from repro.core.bipartite import BMATCH_VECTOR_ROUNDS, bmatch_assign


def greedy_oracle(tok, exp, n_tok, n_exp, budget, cap):
    """Sequential greedy b-matching in stream order — the fixpoint the
    engine's capacitated rounds + exact fallback must reproduce."""
    used_t = np.zeros(n_tok, np.int64)
    used_e = np.zeros(n_exp, np.int64)
    out = np.zeros(len(tok), bool)
    for i, (t, e) in enumerate(zip(tok, exp)):
        if t < 0:
            continue
        if used_t[t] < budget and used_e[e] < cap:
            out[i] = True
            used_t[t] += 1
            used_e[e] += 1
    return out


@settings(max_examples=25, deadline=None)
@given(
    n_tok=st.integers(1, 80),
    n_exp=st.integers(1, 16),
    budget=st.integers(1, 4),
    cap=st.integers(1, 32),
    m=st.integers(1, 300),
    vector_rounds=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bmatch_equals_sequential_greedy(
    n_tok, n_exp, budget, cap, m, vector_rounds, seed
):
    """EXACT equality with the stream-order greedy — implies maximality,
    capacity-respect, and priority order all at once."""
    rng = np.random.default_rng(seed)
    tok = rng.integers(-1, n_tok, m).astype(np.int32)  # -1 = invalid slots
    exp = rng.integers(0, n_exp, m).astype(np.int32)
    accept = np.asarray(
        bmatch_assign(
            jnp.asarray(tok), jnp.asarray(exp),
            num_tokens=n_tok, num_experts=n_exp,
            token_budget=budget, expert_capacity=cap,
            tile_size=64, vector_rounds=vector_rounds,
        )
    )
    want = greedy_oracle(tok, exp, n_tok, n_exp, budget, cap)
    assert np.array_equal(accept, want)
    # capacity constraints never violated (implied, asserted explicitly)
    ok = accept & (tok >= 0)
    assert np.bincount(tok[ok], minlength=n_tok).max(initial=0) <= budget
    assert np.bincount(exp[ok], minlength=n_exp).max(initial=0) <= cap


@settings(max_examples=20, deadline=None)
@given(
    n_tok=st.integers(1, 60),
    n_exp=st.integers(1, 10),
    m=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_rank_impls_bit_equal(n_tok, n_exp, m, seed):
    """matrix / sort / scatter rank builders compute the identical per-side
    rank function (the capacitated analogue of the unit blocked-impl pin)."""
    rng = np.random.default_rng(seed)
    valid = jnp.asarray(rng.random(m) > 0.1)
    u = jnp.asarray(rng.integers(0, n_tok, m), jnp.int32)
    v = jnp.asarray(rng.integers(0, n_exp, m), jnp.int32)
    free = jnp.asarray(rng.random(m) > 0.4) & valid
    fns = {
        "matrix": engine.ranks_from_matrix(u, v, valid),
        "sort": engine.ranks_by_claim_sort(u, v, valid, n_tok, n_exp),
        "scatter": engine.ranks_by_claim_scatter(u, v, valid, n_tok, n_exp),
    }
    got = {k: fn(free) for k, fn in fns.items()}
    ref_u, ref_v = got["matrix"]
    ref_u = np.where(np.asarray(free), np.asarray(ref_u), 0)
    ref_v = np.where(np.asarray(free), np.asarray(ref_v), 0)
    for name, (ru, rv) in got.items():
        # ranks are only consumed under the free mask; compare there
        assert np.array_equal(np.where(np.asarray(free), np.asarray(ru), 0),
                              ref_u), name
        assert np.array_equal(np.where(np.asarray(free), np.asarray(rv), 0),
                              ref_v), name


def test_bmatch_equals_sequential_greedy_seeded():
    """Hypothesis-free twin of the oracle property (fixed shapes — one
    compile, many data draws) so minimal containers still pin it."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        tok = rng.integers(-1, 40, 256).astype(np.int32)
        exp = rng.integers(0, 8, 256).astype(np.int32)
        accept = np.asarray(
            bmatch_assign(
                jnp.asarray(tok), jnp.asarray(exp),
                num_tokens=40, num_experts=8,
                token_budget=2, expert_capacity=10, tile_size=64,
            )
        )
        assert np.array_equal(accept, greedy_oracle(tok, exp, 40, 8, 2, 10))


def test_conflict_methods_identical_output():
    """End-to-end: forcing each rank implementation through bmatch_assign
    never changes the accept mask."""
    rng = np.random.default_rng(7)
    m = 512
    tok = jnp.asarray(rng.integers(0, 100, m), jnp.int32)
    exp = jnp.asarray(rng.integers(0, 8, m), jnp.int32)
    outs = {}
    for method in ("auto", "matrix", "sort", "scatter"):
        outs[method] = np.asarray(
            bmatch_assign(
                tok, exp, num_tokens=100, num_experts=8,
                token_budget=2, expert_capacity=20, tile_size=128,
                conflict_method=method,
            )
        )
    for method, out in outs.items():
        assert np.array_equal(out, outs["auto"]), method


@settings(max_examples=20, deadline=None)
@given(
    n_tok=st.integers(2, 60),
    n_exp=st.integers(1, 30),
    m=st.integers(1, 250),
    vector_rounds=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_unit_capacity_bit_identical_to_unit_engine(
    n_tok, n_exp, m, vector_rounds, seed
):
    """caps (1, 1) degenerate case: tile_pass_capacitated must match the
    unit-capacity engine (run_first_claim_rounds + greedy_fallback_rounds
    via tile_pass) bit for bit — matched mask, conflicts counter, AND the
    fallback decision — on the experts-offset unipartite encoding."""
    rng = np.random.default_rng(seed)
    tok = rng.integers(-1, n_tok, m).astype(np.int32)
    exp = rng.integers(0, n_exp, m).astype(np.int32)
    valid = tok >= 0

    used_u = jnp.zeros((n_tok,), jnp.int32)
    used_v = jnp.zeros((n_exp,), jnp.int32)
    (uu, uv), matched_c, conf_c, fb_c = engine.tile_pass_capacitated(
        used_u, used_v, jnp.asarray(tok), jnp.asarray(exp),
        cap_u=1, cap_v=1, vector_rounds=vector_rounds,
    )

    # unit engine on the same tile: experts offset into a shared id space
    n = n_tok + n_exp
    u1 = jnp.asarray(np.where(valid, tok, -1), jnp.int32)
    v1 = jnp.asarray(np.where(valid, exp + n_tok, 0), jnp.int32)
    state0 = jnp.zeros((n,), jnp.uint8)
    state, matched_1, conf_1, fb_1 = engine.tile_pass(
        state0, u1, v1, n=n, vector_rounds=vector_rounds
    )

    assert np.array_equal(np.asarray(matched_c), np.asarray(matched_1))
    assert np.array_equal(np.asarray(conf_c), np.asarray(conf_1))
    assert bool(fb_c) == bool(fb_1)
    # states agree: used == 1 exactly where the unit state is MCHD
    su = np.asarray(state)
    assert np.array_equal(np.asarray(uu) >= 1, su[:n_tok] == engine.MCHD)
    assert np.array_equal(np.asarray(uv) >= 1, su[n_tok:] == engine.MCHD)


def test_rounds_sensitivity():
    """vector_rounds is pure tuning (rounds-invariant output) and the
    documented default of 2 is what retires the common cross-side chains
    without entering the vmap-hostile while_loop fallback.

    Chain instance (single tile): A=(t1,e1), B=(t1,e2), C=(t2,e2), all
    budgets/capacities 1. Round 1: A commits, B is token-blocked by A, C is
    expert-blocked by the still-free B. Round 2: B is dead (t1 full), which
    unblocks C. So one round needs the fallback; two rounds don't."""
    tok = jnp.asarray([1, 1, 2], jnp.int32)
    exp = jnp.asarray([1, 2, 2], jnp.int32)
    kw = dict(num_tokens=3, num_experts=3, token_budget=1,
              expert_capacity=1, tile_size=64, with_stats=True)
    results = {}
    for vr in (1, 2, 3, 5):
        accept, stats = bmatch_assign(tok, exp, vector_rounds=vr, **kw)
        results[vr] = (np.asarray(accept),
                       int(stats["fallback_tiles"]), int(stats["conflicts"]))
    for vr, (accept, _, _) in results.items():
        assert accept.tolist() == [True, False, True], vr  # rounds-invariant
    assert results[1][1] == 1   # vr=1: chain survives into the fallback
    assert results[2][1] == 0   # vr=2: decided in the unrolled rounds
    assert results[BMATCH_VECTOR_ROUNDS][1] == 0  # the default stays safe


def test_first_k_single_round():
    """Why the old private router needed vector_rounds ~= budget and the
    engine does not: a token's budget-k in-tile candidates commit in ONE
    round under the first-K rule (rank < room), not one per round."""
    tok = jnp.asarray([0, 0, 0], jnp.int32)
    exp = jnp.asarray([0, 1, 2], jnp.int32)
    accept, stats = bmatch_assign(
        tok, exp, num_tokens=1, num_experts=3, token_budget=3,
        expert_capacity=1, tile_size=64, vector_rounds=1, with_stats=True,
    )
    assert np.asarray(accept).all()
    assert int(stats["fallback_tiles"]) == 0
    assert int(stats["conflicts"]) == 0


def test_oversubscribed_expert_dies_without_fallback():
    """Structural oversubscription (hot expert) resolves in the unrolled
    rounds: round 1 commits the first `capacity` claims, the rest observe a
    full expert and die — no free edge remains for the fallback."""
    m = 64
    tok = jnp.arange(m, dtype=jnp.int32)
    exp = jnp.zeros((m,), jnp.int32)
    accept, stats = bmatch_assign(
        tok, exp, num_tokens=m, num_experts=1, token_budget=1,
        expert_capacity=5, tile_size=64, vector_rounds=1, with_stats=True,
    )
    assert np.asarray(accept).tolist() == [True] * 5 + [False] * (m - 5)
    assert int(stats["fallback_tiles"]) == 0


def test_used_counts_cross_tiles():
    """The scan carry makes the stream-order greedy global: capacity
    consumed in tile 0 is visible to tile 1."""
    tok = jnp.asarray([0, 1, 2, 3], jnp.int32)
    exp = jnp.asarray([0, 0, 0, 1], jnp.int32)
    accept = bmatch_assign(
        tok, exp, num_tokens=4, num_experts=2, token_budget=1,
        expert_capacity=2, tile_size=2,   # two tiles of two edges
    )
    assert np.asarray(accept).tolist() == [True, True, False, True]
