"""End-to-end behaviour: train driver runs, loss decreases, checkpoint
restart resumes exactly, serve driver decodes."""
import os

import numpy as np
import pytest

from repro.launch.train import train
from repro.launch.serve import serve


def test_train_loss_decreases(tmp_path):
    losses = train(
        "qwen1.5-0.5b", smoke=True, steps=20, batch_size=4, seq_len=64,
        ckpt_dir=None, microbatches=1,
    )
    assert len(losses) == 20
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]


def test_train_checkpoint_restart(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    # run 10 steps, checkpointing every 5
    l1 = train("llama3.2-1b", smoke=True, steps=10, batch_size=2, seq_len=64,
               ckpt_dir=ckpt, checkpoint_every=5)
    # restart: should resume from step 10 and do nothing more
    l2 = train("llama3.2-1b", smoke=True, steps=10, batch_size=2, seq_len=64,
               ckpt_dir=ckpt, checkpoint_every=5)
    assert l2 == []   # fully resumed, no steps re-run

    # extend to 14 steps from the checkpoint
    l3 = train("llama3.2-1b", smoke=True, steps=14, batch_size=2, seq_len=64,
               ckpt_dir=ckpt, checkpoint_every=5)
    assert len(l3) == 4


def test_train_with_microbatches_matches_shapes():
    losses = train("mamba2-130m", smoke=True, steps=4, batch_size=4,
                   seq_len=64, ckpt_dir=None, microbatches=2)
    assert len(losses) == 4
    assert np.isfinite(losses).all()


def test_serve_decodes():
    outputs = serve("qwen1.5-0.5b", smoke=True, num_requests=3, slots=2,
                    prompt_len=16, max_new=4)
    assert len(outputs) == 3
    for toks in outputs.values():
        assert 1 <= len(toks) <= 4
