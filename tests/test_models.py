"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting output shapes and no NaNs — the per-arch contract from
the assignment. Plus family-specific consistency checks (SSD train==decode,
rolling-window SWA cache)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, TrainConfig, get_smoke_config
from repro.launch import adapters
from repro.launch.steps import make_train_step
from repro.optim import adamw

B, SEQ = 2, 64


def smoke_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "vlm":
        n_img, gh, gw = 16, 4, 4
        batch["tokens"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, SEQ - n_img)), jnp.int32
        )
        batch["mask"] = jnp.ones((B, SEQ - n_img), bool)
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, n_img, cfg.d_model)), jnp.float32
        )
        from repro.models.vlm import make_mrope_positions
        batch["mrope_positions"] = make_mrope_positions(B, SEQ, n_img, (gh, gw))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, SEQ)), jnp.int32
        )
        batch["mask"] = jnp.ones((B, SEQ), bool)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    params = adapters.init_fn(jax.random.PRNGKey(0), cfg)
    batch = smoke_batch(cfg)

    hidden, head, tr, targets, mask = adapters.train_hidden(params, batch, cfg)
    assert hidden.shape[-1] == cfg.d_model
    assert not bool(jnp.any(jnp.isnan(hidden))), f"{arch}: NaN hidden"

    opt = adamw.init_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, o2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert loss > 0
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_decreases(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(total_steps=30, warmup_steps=2, learning_rate=5e-3)
    params = adapters.init_fn(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = smoke_batch(cfg)  # same batch -> loss must drop fast
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch}: {losses}"


DECODE_ARCHS = [a for a in ARCH_IDS]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    params = adapters.init_fn(jax.random.PRNGKey(0), cfg)
    batch = smoke_batch(cfg)
    logits, cache = adapters.prefill_fn(params, batch, cfg, max_len=SEQ + 8)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = adapters.decode_fn(params, cache, tok[:, :1], cfg)
        assert logits.shape[-1] == cfg.vocab_size
        assert not bool(jnp.any(jnp.isnan(logits))), arch
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


def test_ssd_decode_matches_train_forward():
    """SSD duality check: token-by-token recurrent decode reproduces the
    chunked train-mode forward logits."""
    from repro.models import ssm as S
    cfg = get_smoke_config("mamba2-130m")
    params = S.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 32)), jnp.int32)
    train_logits = S.forward(params, tokens, cfg)        # [1, 32, V]

    cache = S.init_cache(cfg, 1, 32)
    outs = []
    for t in range(32):
        logits, cache = S.decode_step(params, cache, tokens[:, t : t + 1], cfg)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec_logits - train_logits)))
    assert err < 2e-2, err


def test_swa_rolling_cache_matches_full_cache():
    """Sliding-window decode with a rolling window-sized cache must equal
    decode with a full-length cache (mixtral-style SWA).

    Run dense (num_experts=0): capacity-limited MoE routing is batched over
    the whole sequence, so teacher-forced forward and single-token decode can
    legitimately route a token differently (capacity pressure differs) — a
    data-dependent divergence that has nothing to do with the rolling cache
    under test here."""
    import dataclasses
    from repro.models import transformer as T
    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x7b"), num_experts=0  # sliding_window=32
    )
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 40)), jnp.int32)

    # rolling cache sized by the window
    _, cache_roll = T.prefill(params, prompt, cfg, max_len=64)
    assert cache_roll["k"].shape[2] == cfg.sliding_window
    # reference: replay decode from a long cache via teacher-forced forward
    ref_logits = T.forward(params, prompt, cfg)

    tok = prompt[:, -1:]
    logits_roll, _ = T.decode_step(params, dict(cache_roll, cur=cache_roll["cur"] - 1,
                                                k=cache_roll["k"], v=cache_roll["v"]),
                                   tok, cfg)
    err = float(jnp.max(jnp.abs(logits_roll[:, -1] - ref_logits[:, -1])))
    assert err < 5e-2, err


def test_full_configs_construct():
    """The FULL configs build abstract params with the published shapes (no
    allocation — eval_shape only)."""
    from repro.configs import get_config
    import math
    expected_params = {
        "llama3-405b": (390e9, 430e9),
        "qwen1.5-110b": (95e9, 120e9),
        "mixtral-8x7b": (42e9, 50e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "qwen1.5-0.5b": (0.4e9, 0.65e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "granite-moe-3b-a800m": (2.0e9, 4.0e9),
        "zamba2-2.7b": (2.0e9, 3.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.2e9),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        tree = jax.eval_shape(lambda c=cfg: adapters.init_fn(jax.random.PRNGKey(0), c))
        n = sum(math.prod(l.shape) for l in jax.tree.leaves(tree))
        lo, hi = expected_params[arch]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
