"""APRAM interleaving conformance (ISSUE 9 tentpole; DESIGN.md §13).

Four layers, each feeding the next:

1. **Model soundness** — the step-level model (``repro.testing.apram``)
   enforces its own invariants: every seeded protocol mutation is caught
   by a per-step check on contended schedules, malformed schedules are
   rejected, and non-strict mode records instead of raising.
2. **Schedule-independence, exhaustively** — for tiny instances (V <= 8)
   EVERY interleaving of the atomic events ends in a valid maximal
   matching (the paper's APRAM safety claim, proved by enumeration at
   small scale), and the zoo of adversarial schedulers covers larger
   instances.
3. **Differential conformance** — every production entry point's mask is
   pinned as ONE reachable APRAM trace of the same edge stream
   (``oracle.pin_trace`` executes the matched-first witness through the
   checked model), at both ``StateSpec.u8()`` and ``legacy_i32()``.
   Forced-D=4 ``distributed_skipper`` runs in a subprocess.
4. **Fuzz corpus** — the checked-in regression corpus
   (``tests/fuzz_corpus/``) replays clean, and the fuzz CLI's mutation
   canary demonstrably fails (the property the CI job relies on).
"""
import json
import math
import sys
from pathlib import Path

import numpy as np
import pytest

from strategies import adversarial_edge_list, run_subprocess

from repro.testing import (
    MAX_EXHAUSTIVE_EVENTS,
    MUTATIONS,
    ApramViolation,
    ConformanceError,
    bipartite_stream,
    exhaustive_schedules,
    hub_contention,
    pin_entry_points,
    pin_trace,
    random_schedule,
    round_robin,
    run_schedule,
    stream_order,
    sweep,
    witness_schedule,
)

TOOLS = Path(__file__).resolve().parent.parent / "tools"
CORPUS = Path(__file__).resolve().parent / "fuzz_corpus"


# ---------------------------------------------------------------------------
# 1. model soundness
# ---------------------------------------------------------------------------
def test_schedule_must_be_permutation():
    u, v = np.array([0, 1]), np.array([1, 2])
    with pytest.raises(ValueError, match="permutation"):
        run_schedule((u, v, 3), [0, 0])
    with pytest.raises(ValueError, match="permutation"):
        run_schedule((u, v, 3), [0])


def test_unknown_mutation_rejected():
    with pytest.raises(ValueError, match="unknown mutation"):
        run_schedule((np.array([0]), np.array([1]), 2), [0],
                     mutation="nonsense")


def test_invalid_edges_are_skipped_events():
    # self-loop, negative, out-of-range: decided, never matched
    u = np.array([0, -1, 2, 0])
    v = np.array([0, 3, 9, 1])
    r = run_schedule((u, v, 3), stream_order(4))
    assert list(r.matched) == [False, False, False, True]
    assert r.decided.all()


@pytest.mark.parametrize("mname", sorted(MUTATIONS))
def test_every_mutation_is_caught(mname):
    """Each seeded protocol bug trips a per-step invariant on at least one
    schedule of a contended instance — the harness has teeth."""
    g = adversarial_edge_list(seed=1, n=16, m=24)
    caught = None
    for seed in range(6):
        try:
            run_schedule(g, random_schedule(g.num_edges, seed),
                         mutation=mname)
            run_schedule(g, stream_order(g.num_edges), mutation=mname)
        except ApramViolation as err:
            caught = err
            break
    assert caught is not None, f"mutation {mname} survived every schedule"
    assert caught.invariant, caught


def test_non_strict_records_instead_of_raising():
    g = adversarial_edge_list(seed=1, n=16, m=24)
    r = run_schedule(g, stream_order(g.num_edges),
                     mutation="skip_partner_check", strict=False)
    assert r.violations, "expected recorded violations"
    assert all(isinstance(x, ApramViolation) for x in r.violations)


def test_round_robin_and_hub_schedules_are_permutations():
    g = adversarial_edge_list(seed=3, n=16, m=24)
    m = g.num_edges
    for s in (round_robin(m, 3), round_robin(m, 100), hub_contention(g),
              random_schedule(m, 9)):
        assert np.array_equal(np.sort(s), np.arange(m))


def test_exhaustive_refuses_large_m():
    with pytest.raises(ValueError, match="refused"):
        list(exhaustive_schedules(MAX_EXHAUSTIVE_EVENTS + 1))


# ---------------------------------------------------------------------------
# 2. schedule-independence
# ---------------------------------------------------------------------------
# Tiny instances, V <= 8, m <= 7 events (7! = 5040 schedules each). Shapes
# chosen for contention: odd cycles, stars with duplicate slots, a clique,
# self-loops and padding in the stream.
TINY = {
    "triangle": ([0, 1, 2], [1, 2, 0], 3),
    "path6": ([0, 1, 2, 3, 4], [1, 2, 3, 4, 5], 6),
    "star_dup": ([0, 0, 0, 0, 0], [1, 2, 3, 1, 2], 5),
    "cycle5": ([0, 1, 2, 3, 4], [1, 2, 3, 4, 0], 5),
    "k4": ([0, 0, 0, 1, 1, 2], [1, 2, 3, 2, 3, 3], 4),
    "hazards": ([0, 0, 2, 2, -1, 3], [0, 1, 3, 3, 1, 4], 8),
    "two_hubs": ([0, 0, 0, 1, 1, 1, 0], [2, 3, 4, 2, 3, 4, 1], 8),
}


@pytest.mark.slow
@pytest.mark.parametrize("gname", sorted(TINY))
def test_exhaustive_every_interleaving_valid_maximal(gname):
    """The APRAM safety claim by enumeration: every one of the m!
    schedules passes per-step checks and quiesces valid+maximal."""
    u, v, n = TINY[gname]
    u, v = np.asarray(u), np.asarray(v)
    assert n <= 8 and len(u) <= 7
    outcomes = set()
    count = 0
    for s in exhaustive_schedules(len(u)):
        r = run_schedule((u, v, n), s)  # strict: raises on any violation
        outcomes.add(r.matching_key())
        count += 1
    assert count == math.factorial(len(u))
    assert len(outcomes) >= 1


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_adversary_sweep_on_contended_graphs(seed):
    g = adversarial_edge_list(seed=seed, n=48, m=128)
    results = sweep(g, seeds=(seed, seed + 100), threads=(2, 7))
    # all schedules quiesce; matchings may differ, sizes within the classic
    # 2x bound of each other
    sizes = sorted(r.num_matches for r in results)
    assert sizes[0] >= 1
    assert sizes[-1] <= 2 * sizes[0]


def test_stream_order_model_equals_sgmm():
    """The identity schedule's outcome IS the sequential greedy oracle."""
    from repro.core.sgmm import sgmm

    g = adversarial_edge_list(seed=5, n=48, m=128)
    model = run_schedule(g, stream_order(g.num_edges))
    np.testing.assert_array_equal(
        model.matched, np.asarray(sgmm(g).match_mask))


# ---------------------------------------------------------------------------
# 3. differential conformance — production entry points as APRAM traces
# ---------------------------------------------------------------------------
def test_witness_schedule_shape():
    mask = np.array([False, True, False, True])
    np.testing.assert_array_equal(
        witness_schedule(None, mask), [1, 3, 0, 2])


def test_pin_trace_rejects_non_maximal():
    g = adversarial_edge_list(seed=2, n=16, m=24)
    from repro.core.sgmm import sgmm

    mask = np.asarray(sgmm(g).match_mask).copy()
    pin_trace(g, mask, label="sgmm")  # the real mask pins
    k = int(np.flatnonzero(mask)[0])
    mask[k] = False  # drop one matched edge: not maximal anymore
    with pytest.raises(ConformanceError) as exc:
        pin_trace(g, mask, label="sgmm")
    assert exc.value.first_mismatch >= 0


def test_pin_trace_rejects_double_booking():
    u, v = np.array([0, 0]), np.array([1, 2])
    with pytest.raises((ConformanceError, ApramViolation)):
        pin_trace((u, v, 3), np.array([True, True]))


@pytest.mark.slow
def test_entry_points_pin_at_both_state_widths():
    """The acceptance-criteria matrix: skipper, skipper_match (xla AND
    interpreted-Pallas, boundary epilogue included — window < V forces
    cross-window edges), distributed D=1, chaos-recover; each at u8 and
    legacy_i32."""
    from repro.graphs.generators import rmat_graph

    g = rmat_graph(scale=7, edge_factor=2, seed=3)  # V=128 > window=64
    out = pin_entry_points(g, window=64, tile_size=32)
    expected = {
        f"{entry}@{spec}"
        for entry in ("skipper", "skipper_match_xla", "skipper_match_pallas",
                      "distributed", "chaos_recover")
        for spec in ("u8", "legacy_i32")
    }
    assert set(out) == expected
    for name, trace in out.items():
        assert trace.num_matches > 0, name


def test_bmatch_unit_capacity_pins_as_bipartite_trace():
    import jax.numpy as jnp

    from repro.core.bipartite import bmatch_assign
    from strategies import random_candidate_stream

    tok, exp = random_candidate_stream(0, 12, 6, 40, invalid=0.1)
    accept = np.asarray(bmatch_assign(
        jnp.asarray(tok), jnp.asarray(exp), num_tokens=12, num_experts=6,
        token_budget=1, expert_capacity=1, tile_size=16,
    ))
    stream = bipartite_stream(tok, exp, num_tokens=12, num_experts=6)
    pin_trace(stream, accept, label="bmatch")


_D4_PIN_SCRIPT = r"""
import numpy as np
import jax
from repro.core.distributed import distributed_skipper
from repro.core.statespec import StateSpec
from repro.graphs.generators import erdos_renyi_graph
from repro.testing import pin_trace

assert jax.device_count() == 4, jax.device_count()
g = erdos_renyi_graph(400, 1600, seed=2)
for spec in (StateSpec.u8(), StateSpec.legacy_i32()):
    res, stats = distributed_skipper(g, block_size=64, spec=spec)
    assert stats.ok
    pin_trace(g, np.asarray(res.match_mask), label="dist-D4-dispersed")
    res, stats = distributed_skipper(
        g, block_size=64, tile_size=64, window=128, reorder="degree",
        backend="xla", spec=spec)
    assert stats.ok
    pin_trace(g, np.asarray(res.match_mask), label="dist-D4-sharded")
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_distributed_forced_d4_pins_as_trace():
    """Forced 4-device runs (both schedules, both state widths) stay
    reachable APRAM traces — device parallelism is just another schedule."""
    run_subprocess(_D4_PIN_SCRIPT, num_devices=4)


# ---------------------------------------------------------------------------
# 4. fuzz corpus + canary
# ---------------------------------------------------------------------------
def _fuzz_mod():
    if str(TOOLS) not in sys.path:
        sys.path.insert(0, str(TOOLS))
    import fuzz_matching

    return fuzz_matching


def test_fuzz_corpus_replays_clean():
    """Every checked-in regression record passes against today's code."""
    fm = _fuzz_mod()
    records = sorted(CORPUS.glob("*.json"))
    assert records, "fuzz corpus is missing"
    for path in records:
        rec = json.loads(path.read_text())
        assert rec["version"] == fm.CORPUS_VERSION, path.name
        assert fm.replay_record(rec), f"{path.name}: {rec['error']}"


def test_corpus_covers_every_mutation():
    """The corpus keeps one minimized catcher instance per known protocol
    mutation (provenance: shrunk from the mutation's own counterexample)."""
    names = {p.stem for p in CORPUS.glob("mutation_*.json")}
    assert names == {f"mutation_{m}" for m in MUTATIONS}


@pytest.mark.fuzz
def test_fuzz_cli_clean_smoke(tmp_path):
    fm = _fuzz_mod()
    rc = fm.main(["--iterations", "3", "--time-budget", "120",
                  "--artifacts", str(tmp_path)])
    assert rc == 0
    assert not list(tmp_path.glob("*.json"))


@pytest.mark.fuzz
def test_fuzz_cli_mutation_canary_fails(tmp_path):
    """--mutation commit_before_reserve MUST exit 1 and write a minimized
    counterexample — proof the fuzzer can actually catch a protocol bug."""
    fm = _fuzz_mod()
    rc = fm.main(["--mutation", "commit_before_reserve",
                  "--iterations", "20", "--time-budget", "120",
                  "--max-counterexamples", "1",
                  "--artifacts", str(tmp_path)])
    assert rc == 1
    arts = list(tmp_path.glob("*.json"))
    assert arts
    rec = json.loads(arts[0].read_text())
    assert rec["mutation"] == "commit_before_reserve"
    assert rec["live_edges"] <= 6  # shrinking worked
