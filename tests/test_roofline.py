"""Roofline HLO analysis: trip-count correction is exact on scans; collective
parse sees sharded-program collectives; cost_analysis undercount documented."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.roofline.hlo_parse import analyze_hlo
from repro.roofline.analysis import analyze, model_flops, PEAK_FLOPS


def test_scan_trip_count_exact():
    def scanned(x, w):
        def body(x, _):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, None, length=8)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, w).compile()
    res = analyze_hlo(c.as_text())
    assert res.dot_flops == 8 * 2 * 256**3
    assert res.while_trip_counts == [8]
    # the raw cost_analysis undercount this module guards against:
    assert compat.cost_analysis(c)["flops"] == 2 * 256**3


def test_nested_scan_trip_counts():
    def nested(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=5)
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(nested).lower(x, w).compile()
    res = analyze_hlo(c.as_text())
    assert res.dot_flops == 15 * 2 * 128**3
    assert sorted(res.while_trip_counts) == [3, 5]


def test_unrolled_matches_scanned():
    def unrolled(x, w):
        for _ in range(4):
            x = x @ w
        return x

    def scanned(x, w):
        def body(x, _):
            return x @ w, None
        return jax.lax.scan(body, x, None, length=4)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    f_u = analyze_hlo(jax.jit(unrolled).lower(x, w).compile().as_text()).dot_flops
    f_s = analyze_hlo(jax.jit(scanned).lower(x, w).compile().as_text()).dot_flops
    assert f_u == f_s == 4 * 2 * 128**3


def test_analyze_terms_positive():
    def f(x, w):
        return jnp.tanh(x @ w)

    x = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    compiled = jax.jit(f).lower(x, w).compile()
    terms = analyze(compiled)
    assert terms.flops == 2 * 512**3
    assert terms.hbm_bytes > 3 * 512 * 512 * 2   # >= operands + result
    assert terms.compute_s == terms.flops / PEAK_FLOPS
    assert terms.dominant in ("compute", "memory", "collective")


def test_model_flops_shapes():
    from repro.configs import get_config, get_shape
    cfg = get_config("llama3.2-1b")
    n = int(1.2e9)
    train = model_flops(cfg, get_shape("train_4k"), n, n)
    assert train == 6.0 * n * 256 * 4096
    dec = model_flops(cfg, get_shape("decode_32k"), n, n)
    assert dec == 2.0 * n * 128
