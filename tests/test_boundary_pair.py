"""Block-pair boundary epilogue (DESIGN.md §10): schedule grouping
invariants, pinned bit-identity of the scalar-prefetch Pallas kernel against
the jnp ``tile_pass_pair`` twin across the shapes the grouping must survive
(odd V, V not divisible by window, all-boundary streams, same-block pairs,
empty global tiers), a hypothesis sweep over random graphs, the single-trace
proof that the new epilogue still joins the one compilation unit, and the
lru_cache'd builder identity."""
import numpy as np
import pytest
import jax.numpy as jnp

from strategies import given, random_edge_list, settings, st  # noqa: E402

from repro.core import assert_matching, engine
from repro.graphs import erdos_renyi_graph
from repro.graphs.types import EdgeList
from repro.graphs.windows import build_window_schedule
from repro.kernels.skipper_match import skipper_match, pipeline_trace_count
from repro.kernels.skipper_match.kernel import (
    build_boundary_matcher,
    build_pipeline_matcher,
    build_window_matcher,
)


def _graph(rng, n, m):
    return random_edge_list(rng, n, m, canonical=True)


def _check_grouping(s):
    """The schedule invariants the kernel's aliasing contract relies on:
    every boundary tile holds edges of exactly ONE (blk_u, blk_v) pair,
    pairs are contiguous in lexicographic order, offset-local ids
    reconstruct the global ids, and the stream stays a single pass (stable
    stream order within each pair)."""
    W, T = s.window, s.tile_size
    nbt = s.num_boundary_tiles
    assert s.boundary_blk_u.shape == (nbt,)
    assert s.boundary_blk_v.shape == (nbt,)
    assert s.num_boundary_padded == nbt * T
    key_prev = -1
    for k in range(nbt):
        bu, bv = int(s.boundary_blk_u[k]), int(s.boundary_blk_v[k])
        assert 0 <= bu <= bv < s.num_windows  # canonical u <= v
        sl = slice(k * T, (k + 1) * T)
        real = s.boundary_index[sl] >= 0
        gu, gv = s.boundary_u[sl][real], s.boundary_v[sl][real]
        # every real edge of the tile lives in THIS tile's pair
        np.testing.assert_array_equal(gu // W, bu)
        np.testing.assert_array_equal(gv // W, bv)
        # offset-local ids reconstruct the global ids
        ul = s.boundary_ulocal[sl][real]
        vl = s.boundary_vlocal[sl][real]
        off = W if bv != bu else 0
        np.testing.assert_array_equal(bu * W + ul, gu)
        np.testing.assert_array_equal(bv * W + vl - off, gv)
        assert ((ul >= 0) & (ul < W)).all()
        assert ((vl >= off) & (vl < off + W)).all()
        # pairs are grouped: tile keys never decrease (no interleaving)
        key = bu * s.num_windows + bv
        assert key >= key_prev
        key_prev = key
    # stable within pair: stream order preserved among the real slots
    real = s.boundary_index >= 0
    keys = (s.boundary_u[real] // W) * s.num_windows + s.boundary_v[real] // W
    idx = s.boundary_index[real]
    for kk in np.unique(keys):
        grp = idx[keys == kk]
        assert (np.diff(grp) > 0).all()


def _assert_twins(edges, schedule, label):
    """Pallas block-pair epilogue bit-identical to the jnp twin (mask, state
    AND conflicts), and the result is a valid maximal matching."""
    rp, cp = skipper_match(
        edges, schedule=schedule, backend="pallas", with_conflicts=True
    )
    rx, cx = skipper_match(
        edges, schedule=schedule, backend="xla", with_conflicts=True
    )
    np.testing.assert_array_equal(
        np.asarray(rp.match_mask), np.asarray(rx.match_mask)
    )
    np.testing.assert_array_equal(np.asarray(rp.state), np.asarray(rx.state))
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cx))
    assert_matching(edges, rp.match_mask, label)
    return rp


@pytest.mark.parametrize("n,window,tile", [
    (701, 128, 64),    # odd V
    (700, 256, 64),    # V not divisible by window
    (901, 128, 32),    # both
])
def test_pair_epilogue_pinned_shapes(n, window, tile):
    rng = np.random.default_rng(n)
    edges = _graph(rng, n, 4 * n)
    s = build_window_schedule(edges, window, tile)
    assert s.num_boundary_padded > 0  # the epilogue actually runs
    _check_grouping(s)
    _assert_twins(edges, s, f"pair/{n}")


def test_pair_epilogue_all_boundary_stream():
    """intra == 0: every edge crosses a window boundary, so the entire graph
    is decided by the block-pair epilogue."""
    rng = np.random.default_rng(3)
    u = rng.integers(0, 128, 1500).astype(np.int32)
    v = rng.integers(128, 640, 1500).astype(np.int32)
    edges = EdgeList(jnp.asarray(u), jnp.asarray(v), 640)
    s = build_window_schedule(edges, window=128, tile_size=64)
    assert s.num_intra == 0
    assert s.num_boundary_padded > 0
    _check_grouping(s)
    _assert_twins(edges, s, "pair/all-boundary")


def test_pair_epilogue_same_block_pairs():
    """Coalesced sparse windows put SAME-block pairs (blk_u == blk_v) in the
    global tier; the kernel degenerates them to one block load and the u-row
    write-back wins (tile_pass_pair's v-then-u order)."""
    rng = np.random.default_rng(4)
    # window 0 dense (stays in the window tier), window 2 sparse (coalesced)
    u0 = rng.integers(0, 128, 600).astype(np.int32)
    v0 = rng.integers(0, 128, 600).astype(np.int32)
    u2 = rng.integers(256, 384, 5).astype(np.int32)
    v2 = rng.integers(256, 384, 5).astype(np.int32)
    u = np.concatenate([u0, u2])
    v = np.concatenate([v0, v2])
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    edges = EdgeList(jnp.asarray(lo), jnp.asarray(hi), 384)
    s = build_window_schedule(edges, window=128, tile_size=64)
    assert (s.boundary_blk_u == s.boundary_blk_v).any()
    _check_grouping(s)
    _assert_twins(edges, s, "pair/same-block")


def test_pair_epilogue_empty_global_tier():
    """V <= window: everything is intra, the epilogue is skipped and the
    grouped arrays are empty."""
    g = erdos_renyi_graph(120, 400, seed=5)
    s = build_window_schedule(g, window=128, tile_size=64)
    assert s.num_boundary_padded == 0
    assert s.num_boundary_tiles == 0
    assert s.num_boundary_pairs == 0
    assert s.boundary_blk_u.size == 0
    _assert_twins(g, s, "pair/empty-global")


def test_pair_epilogue_single_trace():
    """The block-pair epilogue still joins the ONE compilation unit: first
    call traces the pipeline once, a repeat with the same schedule shape
    reuses it (zero host round-trips per window or per pair)."""
    rng = np.random.default_rng(6)
    edges = _graph(rng, 555, 2500)
    # unique (window, tile) so no earlier test pre-populated the cache
    s = build_window_schedule(edges, window=96, tile_size=48)
    assert s.num_boundary_padded > 0
    before = pipeline_trace_count()
    skipper_match(edges, schedule=s, backend="pallas")
    assert pipeline_trace_count() == before + 1
    skipper_match(edges, schedule=s, backend="pallas")
    assert pipeline_trace_count() == before + 1, "retraced on same shapes"


def test_builders_are_cached():
    """lru_cache satellite: repeated builder calls with the same static args
    return the SAME pallas_call object (the single-device driver used to
    rebuild per call)."""
    assert build_boundary_matcher(4, 64, 8, 128) is build_boundary_matcher(
        4, 64, 8, 128
    )
    assert build_window_matcher(4, 64, 128) is build_window_matcher(4, 64, 128)
    assert build_pipeline_matcher(2, 4, 64, 128) is build_pipeline_matcher(
        2, 4, 64, 128
    )
    assert build_boundary_matcher(4, 64, 8, 128) is not build_boundary_matcher(
        8, 64, 8, 128
    )


def test_tile_pass_pair_is_concat_tile_pass():
    """tile_pass_pair == tile_pass on the concatenated rows (the kernel's
    bit-identity-by-construction contract), including the same-block
    degenerate case where the u-row write-back must win."""
    rng = np.random.default_rng(7)
    W = 16
    rows = (rng.integers(0, 2, (4, W)) * 2).astype(np.int32)  # ACC/MCHD
    u = rng.integers(0, W, 8).astype(np.int32)
    v = (rng.integers(0, W, 8) + W).astype(np.int32)
    out, mt, cf, tk = engine.tile_pass_pair(
        jnp.asarray(rows), jnp.asarray(u), jnp.asarray(v), 1, 3,
        window=W, vector_rounds=1,
    )
    pair = np.concatenate([rows[1], rows[3]])
    ref_pair, ref_mt, ref_cf, ref_tk = engine.tile_pass(
        jnp.asarray(pair), jnp.asarray(u), jnp.asarray(v),
        n=2 * W, vector_rounds=1,
    )
    exp = rows.copy()
    exp[1] = np.asarray(ref_pair)[:W]
    exp[3] = np.asarray(ref_pair)[W:]
    np.testing.assert_array_equal(np.asarray(out), exp)
    np.testing.assert_array_equal(np.asarray(mt), np.asarray(ref_mt))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(ref_cf))

    # same-block pair: v ids stay in [0, W), row 2 = both halves' home
    vs = rng.integers(0, W, 8).astype(np.int32)
    out2, mt2, _, _ = engine.tile_pass_pair(
        jnp.asarray(rows), jnp.asarray(u), jnp.asarray(vs), 2, 2,
        window=W, vector_rounds=1,
    )
    pair2 = np.concatenate([rows[2], rows[2]])
    ref2, ref_mt2, _, _ = engine.tile_pass(
        jnp.asarray(pair2), jnp.asarray(u), jnp.asarray(vs),
        n=2 * W, vector_rounds=1,
    )
    exp2 = rows.copy()
    exp2[2] = np.asarray(ref2)[:W]  # u half wins; v half was never touched
    np.testing.assert_array_equal(np.asarray(out2), exp2)
    np.testing.assert_array_equal(np.asarray(mt2), np.asarray(ref_mt2))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=900),
    mult=st.integers(min_value=1, max_value=6),
    window=st.sampled_from([64, 128, 256]),
    tile=st.sampled_from([32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dispersed=st.booleans(),
)
def test_pair_epilogue_property(n, mult, window, tile, seed, dispersed):
    """Random graphs x random shapes: grouping invariants hold and the two
    backends stay bit-identical (the hypothesis half of the pinned suite —
    the deterministic pins above run even without hypothesis installed)."""
    rng = np.random.default_rng(seed)
    edges = _graph(rng, n, mult * n)
    s = build_window_schedule(edges, window, tile, dispersed)
    _check_grouping(s)
    _assert_twins(edges, s, "pair/prop")
