"""Chaos matrix for the fault-injection harness + recovery ladder
(DESIGN.md §11).

Every injection site of ``core/faults.FaultPlan`` is driven through every
matcher entry point — ``skipper_match`` (single-device pipeline, XLA twin),
``distributed_skipper`` dispersed and locality-sharded at D=1 in-process,
and both distributed schedules at forced D=4 in a subprocess — and the
recovery ladder (``on_fault="recover"``) must always hand back a matching
that passes ``core/validate.check_matching`` (valid + maximal on the
uncorrupted graph).

Beyond "recovery always completes", this file pins:

* faults actually bite — ``on_fault="report"`` sees nonzero damage for the
  sites that are live at D=1 (drop / corrupt / lose_shard; truncate and
  skip_drain only have teeth when requeues exist, i.e. D > 1);
* fault-free runs report exactly zero on every recovery field (the harness
  compiles to the pre-harness graph when ``faults`` is inactive);
* blast-radius containment: the recovered matching agrees with the
  fault-free run outside the taint closure of the injected damage (the
  victim sets are re-derivable host-side because the fault masks are keyed
  only on ``(plan.seed, size)``);
* ``check_matching`` degenerate inputs (satellite: empty edge list, n == 0,
  out-of-range dead edges must not alias vertex 0).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from strategies import (  # noqa: E402
    given,
    run_subprocess as _run_subprocess,
    settings,
    st,
)

from repro.core import FaultPlan, check_matching
from repro.core.distributed import distributed_skipper
from repro.core.faults import corruption_mask, proposal_drop_mask
from repro.core.types import MCHD
from repro.graphs import (
    EdgeList,
    build_window_schedule,
    erdos_renyi_graph,
)
from repro.kernels.skipper_match import skipper_match


# One plan per injection site, all at the pinned chaos seed. lose_shard=0
# hits row/device 0 which always exists at any D / schedule size.
PLANS = {
    "drop": FaultPlan(seed=7, drop_proposals=0.3),
    "truncate": FaultPlan(seed=7, truncate_retry=0),
    "corrupt": FaultPlan(seed=7, corrupt_state=0.05),
    "lose_shard": FaultPlan(seed=7, lose_shard=0),
    "skip_drain": FaultPlan(seed=7, skip_drain=True),
}

G = erdos_renyi_graph(300, 900, seed=0)
SCHED = build_window_schedule(G, window=128, tile_size=64)


def _assert_valid_maximal(g, mask, label):
    chk = check_matching(g, mask)
    ok_v, ok_m = (bool(x) for x in jax.device_get((chk["valid"], chk["maximal"])))
    assert ok_v and ok_m, f"{label}: valid={ok_v} maximal={ok_m}"


# ---------------------------------------------------------------------------
# in-process chaos matrix (D=1)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("site", sorted(PLANS))
def test_chaos_skipper_match_recovers(site):
    plan = PLANS[site]
    # verify=True makes the recover path self-check: a RuntimeError here is
    # by construction a recovery-ladder bug, not a fault symptom.
    result, report = skipper_match(
        edges=G, schedule=SCHED, backend="xla",
        faults=plan, on_fault="recover", verify=True,
    )
    _assert_valid_maximal(G, result.match_mask, f"skipper_match/{site}")
    assert report.residual_edges >= 0
    if report.residual_edges or report.corrupted_cells:
        assert report.recovery_attempts >= 1


@pytest.mark.chaos
@pytest.mark.parametrize("site", sorted(PLANS))
@pytest.mark.parametrize("kind", ["dispersed", "sharded"])
def test_chaos_distributed_d1_recovers(site, kind):
    plan = PLANS[site]
    kw = (
        dict(block_size=64, tile_size=64)
        if kind == "dispersed"
        else dict(block_size=64, window=128, tile_size=64)
    )
    result, stats = distributed_skipper(
        G, faults=plan, on_fault="recover", verify=True, **kw
    )
    _assert_valid_maximal(G, result.match_mask, f"dist1/{kind}/{site}")
    # the ladder is bounded: at most _MAX_ESCALATIONS re-runs + one replay
    assert int(stats.recovery_attempts) <= 3


def test_faults_actually_bite_report_mode():
    """report mode must SEE the damage (else recover tests prove nothing).

    Sites live at D=1: drop (proposals swallowed before the gather),
    corrupt (out-of-domain state bytes), lose_shard (a window row / device
    contribution zeroed). truncate/skip_drain only bite when requeues
    exist, i.e. D > 1 — pinned inert here so the matrix documents it.
    """
    for site in ("drop", "corrupt", "lose_shard"):
        _, report = skipper_match(
            edges=G, schedule=SCHED, backend="xla",
            faults=PLANS[site], on_fault="report",
        )
        damage = report.residual_edges + report.corrupted_cells
        assert damage > 0, f"skipper_match/{site}: fault did not bite"

        _, stats = distributed_skipper(
            G, block_size=64, tile_size=64,
            faults=PLANS[site], on_fault="report",
        )
        damage = int(stats.residual_edges) + int(stats.corrupted_cells)
        assert damage > 0, f"dispersed/{site}: fault did not bite"

    for site in ("truncate", "skip_drain"):  # inert at D=1: no requeues
        _, report = skipper_match(
            edges=G, schedule=SCHED, backend="xla",
            faults=PLANS[site], on_fault="report",
        )
        assert report.residual_edges == 0 and report.corrupted_cells == 0


def test_corruption_breaks_only_maximality():
    """Out-of-domain bytes can hide vertices (maximality) but can never
    fabricate a matched edge (validity) — the mask, not the state array, is
    ground truth. This is what makes mask-anchored recovery sound."""
    result, _ = skipper_match(
        edges=G, schedule=SCHED, backend="xla",
        faults=PLANS["corrupt"], on_fault="report",
    )
    chk = check_matching(G, result.match_mask)
    assert bool(jax.device_get(chk["valid"]))


def test_fault_free_recovery_fields_are_zero():
    result, report = skipper_match(
        edges=G, schedule=SCHED, backend="xla",
        on_fault="report", verify=True,
    )
    assert report.recovery_attempts == 0
    assert report.residual_edges == 0
    assert report.recovered_matches == 0
    assert report.corrupted_cells == 0

    for kw in (
        dict(block_size=64, tile_size=64),
        dict(block_size=64, window=128, tile_size=64),
    ):
        _, stats = distributed_skipper(G, on_fault="report", verify=True, **kw)
        assert int(stats.recovery_attempts) == 0
        assert int(stats.residual_edges) == 0
        assert int(stats.recovered_matches) == 0
        assert int(stats.corrupted_cells) == 0


def test_inactive_plan_is_the_clean_path():
    """An all-off FaultPlan must produce bit-identical output to faults=None
    (it is normalized away before the compile cache)."""
    base = skipper_match(edges=G, schedule=SCHED, backend="xla")
    same = skipper_match(edges=G, schedule=SCHED, backend="xla",
                         faults=FaultPlan(seed=99))
    assert not FaultPlan(seed=99).active
    assert np.array_equal(np.asarray(base.match_mask),
                          np.asarray(same.match_mask))


def test_policy_validation():
    with pytest.raises(ValueError, match="on_fault"):
        skipper_match(edges=G, schedule=SCHED, backend="xla",
                      on_fault="retry")
    with pytest.raises(ValueError, match="edge list"):
        skipper_match(schedule=SCHED, backend="xla", on_fault="recover")
    with pytest.raises(ValueError, match="on_fault"):
        distributed_skipper(G, block_size=64, on_fault="panic")
    with pytest.raises(ValueError, match="edge"):
        distributed_skipper(None, schedule=SCHED, block_size=64,
                            on_fault="recover")


# ---------------------------------------------------------------------------
# blast-radius containment: recovered run agrees with the fault-free run
# outside the taint closure of the injected damage
# ---------------------------------------------------------------------------

def _seed_taint(plan: FaultPlan) -> np.ndarray:
    """Host-side re-derivation of the direct victim VERTICES of ``plan``
    on ``SCHED`` — possible because the fault masks are keyed only on
    (seed, size). truncate/skip_drain victims are runtime-dependent (which
    edges requeue) so this oracle only covers drop/corrupt/lose_shard."""
    n = G.num_vertices
    tainted = np.zeros(n, bool)
    gu = np.asarray(G.u)
    gv = np.asarray(G.v)

    if plan.drop_proposals > 0.0:
        nb = SCHED.num_boundary_padded
        dm = np.asarray(proposal_drop_mask(plan, nb))
        ws = SCHED.num_rows * SCHED.tiles_per_window * SCHED.tile_size
        src = np.asarray(SCHED.stream_src)
        hit = (src >= ws) & (src < ws + nb)
        hit &= dm[np.clip(src - ws, 0, nb - 1)]
        tainted[gu[hit]] = True
        tainted[gv[hit]] = True

    if plan.corrupt_state > 0.0:
        cm = np.asarray(corruption_mask(plan, SCHED.num_windows * SCHED.window))
        flat = np.nonzero(cm)[0]
        # reorder="none" -> flat renumbered id == original id for ids < n
        tainted[flat[flat < n]] = True

    if plan.lose_shard is not None:
        row = plan.lose_shard % SCHED.num_rows
        w = int(SCHED.window_ids[row])
        lo, hi = w * SCHED.window, min((w + 1) * SCHED.window, n)
        tainted[lo:hi] = True

    return tainted


@pytest.mark.chaos
@pytest.mark.parametrize("site", ["drop", "corrupt", "lose_shard"])
def test_recovery_blast_radius_contained(site):
    """Every edge decided differently by the recovered run must be reachable
    from a direct fault victim through a chain of differing edges: damage
    propagates only along alternating paths, never teleports."""
    plan = PLANS[site]
    base = skipper_match(edges=G, schedule=SCHED, backend="xla")
    rec, _ = skipper_match(
        edges=G, schedule=SCHED, backend="xla",
        faults=plan, on_fault="recover",
    )
    diff = np.asarray(base.match_mask) != np.asarray(rec.match_mask)
    du = np.asarray(G.u)[diff]
    dv = np.asarray(G.v)[diff]

    tainted = _seed_taint(plan)
    assert tainted.any()  # the oracle itself must see victims
    while True:
        hit = tainted[du] | tainted[dv]
        before = tainted.sum()
        tainted[du[hit]] = True
        tainted[dv[hit]] = True
        if tainted.sum() == before:
            break
    untouched = ~(tainted[du] | tainted[dv])
    assert not untouched.any(), (
        f"{site}: {int(untouched.sum())} differing edges outside the taint "
        "closure of the injected fault"
    )


# ---------------------------------------------------------------------------
# property: ANY plan + recover -> valid + maximal (bounded plan space so the
# number of distinct XLA pipelines stays small)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    seed=st.sampled_from(range(4)),
    drop=st.sampled_from([0.0, 0.05, 0.3]),
    corrupt=st.sampled_from([0.0, 0.05]),
    lose=st.sampled_from([None, 0]),
)
def test_property_recover_always_completes(seed, drop, corrupt, lose):
    plan = FaultPlan(
        seed=seed, drop_proposals=drop, corrupt_state=corrupt,
        lose_shard=lose,
    )
    result, _ = skipper_match(
        edges=G, schedule=SCHED, backend="xla",
        faults=plan, on_fault="recover",
    )
    _assert_valid_maximal(G, result.match_mask, f"prop/{plan}")


# ---------------------------------------------------------------------------
# forced multi-device chaos matrix (subprocess, D=4)
# ---------------------------------------------------------------------------

_CHAOS_SCRIPT = r"""
import jax
from repro.core import assert_matching
from repro.core.faults import FaultPlan
from repro.core.distributed import distributed_skipper
from repro.graphs import erdos_renyi_graph

assert jax.device_count() == 4
g = erdos_renyi_graph(300, 900, seed=0)
plans = {
    "drop": FaultPlan(seed=7, drop_proposals=0.3),
    "truncate": FaultPlan(seed=7, truncate_retry=0),
    "corrupt": FaultPlan(seed=7, corrupt_state=0.05),
    "lose_shard": FaultPlan(seed=7, lose_shard=1),
    "skip_drain": FaultPlan(seed=7, skip_drain=True),
}
kinds = (
    ("dispersed", dict(block_size=64, tile_size=64)),
    ("sharded", dict(block_size=64, window=128, tile_size=64)),
)
for name, plan in plans.items():
    for kind, kw in kinds:
        result, stats = distributed_skipper(
            g, faults=plan, on_fault="recover", verify=True, **kw
        )
        assert_matching(g, result.match_mask, f"chaos4/{name}/{kind}")
        assert int(stats.recovery_attempts) <= 3, (name, kind)

# fault-free at D=4: every recovery field exactly zero
for kind, kw in kinds:
    result, stats = distributed_skipper(g, on_fault="report", verify=True, **kw)
    assert int(stats.recovery_attempts) == 0, kind
    assert int(stats.residual_edges) == 0, kind
    assert int(stats.recovered_matches) == 0, kind
    assert int(stats.corrupted_cells) == 0, kind
print("SUBPROCESS_OK")
"""


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.subprocess
def test_chaos_matrix_forced_4dev():
    _run_subprocess(_CHAOS_SCRIPT, num_devices=4)


# ---------------------------------------------------------------------------
# check_matching degenerate inputs (satellite)
# ---------------------------------------------------------------------------

def _empty_graph(n):
    return EdgeList(
        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32), n
    )


def test_check_matching_empty_edges():
    g = _empty_graph(5)
    chk = check_matching(g, jnp.zeros((0,), bool))
    assert bool(chk["valid"]) and bool(chk["maximal"])


def test_check_matching_zero_vertices():
    g = _empty_graph(0)
    chk = check_matching(g, jnp.zeros((0,), bool))
    assert bool(chk["valid"]) and bool(chk["maximal"])


def test_check_matching_dead_edges_do_not_alias_vertex0():
    """Out-of-range / self-loop edges must not count as covering vertex 0:
    the empty matching on a graph whose only real edge is (0, 1) is NOT
    maximal, whatever junk rides along in the stream."""
    g = EdgeList(
        jnp.asarray([0, 3, 7], jnp.int32),
        jnp.asarray([1, 3, 99], jnp.int32),  # self-loop, v out of range
        num_vertices=8,
    )
    mask = jnp.zeros((3,), bool)
    chk = check_matching(g, mask)
    assert bool(chk["valid"])          # empty matching is always valid
    assert not bool(chk["maximal"])    # (0, 1) is free -> not maximal

    # matching the one real edge IS maximal; dead edges stay uncovered junk
    chk = check_matching(g, jnp.asarray([True, False, False]))
    assert bool(chk["valid"]) and bool(chk["maximal"])
