"""Pallas kernel validation (interpret mode): shape/dtype sweeps against the
pure-jnp oracles, per the kernel contract (kernel.py + ops.py + ref.py)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import erdos_renyi_graph, grid_graph, rmat_graph
from repro.core import assert_matching, sgmm
from repro.kernels.skipper_match import (
    skipper_match, skipper_match_window, ref_match_window,
)
from repro.kernels.flash_attention import flash_attention, ref_attention


# ------------------------------------------------------------ skipper ------
@pytest.mark.parametrize("window", [128, 512])
@pytest.mark.parametrize("tile", [64, 128])
@pytest.mark.parametrize("m", [37, 300, 1000])
def test_skipper_kernel_matches_ref_exactly(window, tile, m):
    rng = np.random.default_rng(window * 1000 + tile + m)
    u = rng.integers(-1, window, size=m).astype(np.int32)
    v = rng.integers(0, window, size=m).astype(np.int32)
    st0 = jnp.zeros((window,), jnp.int32)
    s1, m1, c1 = skipper_match_window(
        jnp.asarray(u), jnp.asarray(v), st0, tile_size=tile
    )
    pad = (-m) % tile
    up = np.concatenate([u, np.full(pad, -1, np.int32)]).reshape(-1, tile)
    vp = np.concatenate([v, np.full(pad, -1, np.int32)]).reshape(-1, tile)
    s2, m2, c2 = ref_match_window(jnp.asarray(up), jnp.asarray(vp), st0)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2)[:m])
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2)[:m])


@pytest.mark.parametrize("gname,g", [
    ("grid", grid_graph(30, 30)),
    ("er", erdos_renyi_graph(3000, 9000, seed=7)),
    ("rmat", rmat_graph(11, 8, seed=8)),
])
def test_skipper_kernel_full_graph(gname, g):
    res = skipper_match(g, window=1024, tile_size=128)
    out = assert_matching(g, res.match_mask, f"kernel/{gname}")
    # maximal matching size within the 2x bound of another maximal matching
    ms = int(sgmm(g).num_matches)
    assert out["num_matches"] >= ms / 2


def test_skipper_kernel_matches_ref_without_fallback():
    """Oracle honors fallback=False exactly like the kernel (a dependency
    chain that only the sequential fallback would finish stays unmatched)."""
    u = np.array([0, 1, 2, -1], np.int32)
    v = np.array([1, 2, 3, -1], np.int32)
    st0 = jnp.zeros((8,), jnp.int32)
    s1, m1, c1 = skipper_match_window(
        jnp.asarray(u), jnp.asarray(v), st0, tile_size=4,
        vector_rounds=1, fallback=False,
    )
    s2, m2, c2 = ref_match_window(
        jnp.asarray(u).reshape(1, 4), jnp.asarray(v).reshape(1, 4), st0,
        vector_rounds=1, fallback=False,
    )
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_skipper_kernel_empty_and_selfloops():
    import jax.numpy as jnp
    from repro.graphs.types import EdgeList
    g = EdgeList(jnp.asarray([3, 5, -1], jnp.int32),
                 jnp.asarray([3, 5, -1], jnp.int32), 10)
    res = skipper_match(g, window=16, tile_size=64)
    assert int(res.match_mask.sum()) == 0


# ------------------------------------------------------ flash attention ----
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 256, 64),
    (1, 8, 1, 256, 128),
    (2, 4, 4, 128, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, tol, b, hq, hkv, s, d, causal):
    key = jax.random.PRNGKey(b * 17 + s)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, s, d), dtype)
    k = jax.random.normal(kk, (b, hkv, s, d), dtype)
    v = jax.random.normal(kv, (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = ref_attention(q, k, v, causal=causal)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
    assert err < tol, err


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    ref = ref_attention(q, k, v, causal=True, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_attention_matches_model_attention():
    """Cross-validate the kernel against the model-side chunked attention."""
    from repro.models.layers import gqa_attention_chunked
    key = jax.random.PRNGKey(3)
    b, hq, hkv, s, d = 2, 8, 2, 256, 64
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d), jnp.float32)
    model_out = gqa_attention_chunked(q, k, v, causal=True, q_chunk=128, kv_chunk=64)
    kern_out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, block_q=64, block_k=64,
    ).transpose(0, 2, 1, 3)
    assert float(jnp.max(jnp.abs(model_out - kern_out))) < 1e-4
