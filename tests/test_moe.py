"""MoE routing: the Skipper b-matching router (the paper technique as a
framework feature, since PR 4 built on the capacitated claim engine —
DESIGN.md §9) vs the top-k baseline. Engine-level pins live in
tests/test_bipartite.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

# only the property test needs hypothesis (a [dev] dep)
from strategies import given, settings, st  # noqa: E402

from repro.configs import get_smoke_config
from repro.core.bipartite import bmatch_assign
from repro.models.moe import moe_mlp, init_moe_mlp


@settings(max_examples=25, deadline=None)
@given(
    n_tok=st.integers(1, 200),
    n_exp=st.integers(1, 16),
    budget=st.integers(1, 4),
    cap=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bmatch_capacities(n_tok, n_exp, budget, cap, seed):
    """Invariants: per-token budget and per-expert capacity are never
    violated; the assignment is maximal (no acceptable edge remains)."""
    rng = np.random.default_rng(seed)
    m = n_tok * min(n_exp, budget + 2)
    tok = rng.integers(0, n_tok, m).astype(np.int32)
    exp = rng.integers(0, n_exp, m).astype(np.int32)
    accept = np.asarray(
        bmatch_assign(
            jnp.asarray(tok), jnp.asarray(exp),
            num_tokens=n_tok, num_experts=n_exp,
            token_budget=budget, expert_capacity=cap, tile_size=64,
        )
    )
    tok_used = np.bincount(tok[accept], minlength=n_tok)
    exp_used = np.bincount(exp[accept], minlength=n_exp)
    assert tok_used.max(initial=0) <= budget
    assert exp_used.max(initial=0) <= cap
    # maximality: every rejected edge was blocked by a full token or expert
    # *at its decision point*; at the end, any edge with BOTH sides free would
    # violate maximality.
    for t, e, a in zip(tok, exp, accept):
        if not a:
            assert tok_used[t] >= budget or exp_used[e] >= cap


def test_bmatch_respects_priority_order():
    """Earlier (higher-score) edges win contested capacity."""
    tok = jnp.asarray([0, 1, 2], jnp.int32)
    exp = jnp.asarray([0, 0, 0], jnp.int32)
    accept = bmatch_assign(
        tok, exp, num_tokens=3, num_experts=1,
        token_budget=1, expert_capacity=2, tile_size=64,
    )
    assert accept.tolist() == [True, True, False]


@pytest.mark.parametrize("router", ["skipper", "topk"])
def test_moe_mlp_forward(router):
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = cfg.__class__(**{**cfg.__dict__, "moe_router": router})
    key = jax.random.PRNGKey(0)
    p = init_moe_mlp(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model), jnp.float32)
    out = moe_mlp(x, p, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
    assert float(jnp.abs(out).sum()) > 0


def test_skipper_router_never_overflows_capacity():
    """The matching router enforces capacity by construction — zero dropped
    dispatches at the buffer (top-k must clamp/drop instead)."""
    cfg = get_smoke_config("granite-moe-3b-a800m")
    key = jax.random.PRNGKey(0)
    p = init_moe_mlp(key, cfg)
    # adversarial: all tokens prefer expert 0
    x = jnp.ones((1, 128, cfg.d_model), jnp.float32)
    out = moe_mlp(x, p, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_routers_similar_output_scale():
    cfg = get_smoke_config("mixtral-8x7b")
    key = jax.random.PRNGKey(0)
    p = init_moe_mlp(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model), jnp.float32)
    cfg_t = cfg.__class__(**{**cfg.__dict__, "moe_router": "topk"})
    cfg_s = cfg.__class__(**{**cfg.__dict__, "moe_router": "skipper"})
    o_t = moe_mlp(x, p, cfg_t)
    o_s = moe_mlp(x, p, cfg_s)
    r = float(jnp.linalg.norm(o_s) / (jnp.linalg.norm(o_t) + 1e-9))
    assert 0.3 < r < 3.0, r
