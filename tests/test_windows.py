"""Device-resident window pipeline: schedule correctness, matching
properties across generator families, backend equivalence, and the
zero-host-round-trip guarantee (single trace covers all windows)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import assert_matching, skipper
from repro.graphs import (
    EdgeList, bipartite_graph, grid_graph, ring_graph, rmat_graph,
    star_graph, build_window_schedule, contiguous_chunks,
)
from repro.kernels.skipper_match import skipper_match, pipeline_trace_count

GRAPHS = {
    "rmat": lambda: rmat_graph(10, 8, seed=3),
    "grid": lambda: grid_graph(24, 24),
    "ring": lambda: ring_graph(333),
    "star": lambda: star_graph(200),
    "bipartite": lambda: bipartite_graph(300, 200, 1500, seed=4),
}


# --------------------------------------------------- schedule invariants ---
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("window,tile", [(128, 64), (256, 128)])
def test_schedule_partitions_stream(gname, window, tile):
    """Every valid edge lands in exactly one slot (windowed or boundary);
    local ids are in-range; padding is -1."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=window, tile_size=tile)
    u = np.asarray(g.canonical().u)
    v = np.asarray(g.canonical().v)
    valid = (u >= 0) & (u != v)

    widx = s.edge_index[s.edge_index >= 0]
    bidx = s.boundary_index[s.boundary_index >= 0]
    both = np.concatenate([widx, bidx])
    assert len(both) == len(set(both.tolist())), "edge scheduled twice"
    np.testing.assert_array_equal(np.sort(both), np.nonzero(valid)[0])

    present = s.edge_index >= 0
    assert np.all(s.u_tiles[present] >= 0) and np.all(s.u_tiles[present] < window)
    assert np.all(s.v_tiles[present] >= 0) and np.all(s.v_tiles[present] < window)
    assert np.all(s.u_tiles[~present] == -1) and np.all(s.v_tiles[~present] == -1)
    # slot local ids reconstruct the original global endpoints
    wrow = np.repeat(np.arange(s.num_windows), s.tiles_per_window * s.tile_size).reshape(
        s.num_windows, -1
    )
    np.testing.assert_array_equal(
        s.u_tiles[present] + wrow[present] * window, u[s.edge_index[present]]
    )
    np.testing.assert_array_equal(
        s.v_tiles[present] + wrow[present] * window, v[s.edge_index[present]]
    )


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_schedule_index_roundtrip(gname):
    """stream position <-> (window, tile, lane) round-trips exactly."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=128, tile_size=64)
    s2s = s.slot_to_stream()                 # [W, T, L] -> stream
    inv = s.stream_to_slot()                 # stream -> (w, t, l)
    w, t, l = np.nonzero(s2s >= 0)
    np.testing.assert_array_equal(inv[s2s[w, t, l]], np.stack([w, t, l], axis=1))
    # and the reverse: every scheduled stream position points back at its slot
    k = np.nonzero(inv[:, 0] >= 0)[0]
    wk, tk, lk = inv[k, 0], inv[k, 1], inv[k, 2]
    np.testing.assert_array_equal(s2s[wk, tk, lk], k)


def test_dispersed_deal_within_window():
    """Lane l of tile t holds window-stream slot l * tiles_per_window + t."""
    g = ring_graph(256)  # one window, edges in stream order
    s = build_window_schedule(g, window=256, tile_size=64)
    assert s.num_windows == 1
    s2s = s.slot_to_stream()[0]  # [tiles, lanes]
    tiles = s.tiles_per_window
    for t in range(tiles):
        for l in range(0, 64, 17):
            want = l * tiles + t
            got = s2s[t, l]
            if want < s.num_edges:
                assert got == want
            else:
                assert got == -1


# ------------------------------------------------- matching properties ----
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("window,tile", [(128, 64), (256, 128), (512, 64)])
def test_pipeline_valid_maximal_all_families(gname, window, tile):
    g = GRAPHS[gname]()
    res = skipper_match(g, window=window, tile_size=tile, backend="xla")
    out = assert_matching(g, res.match_mask, f"pipeline/{gname}/w{window}t{tile}")
    # any two maximal matchings are within 2x of each other
    ref, _ = skipper(g, tile_size=128)
    nref = int(ref.num_matches)
    assert nref / 2 <= out["num_matches"] <= 2 * nref


@pytest.mark.parametrize("gname", ["grid", "rmat", "star"])
def test_pipeline_pallas_interpret_matches_xla_exactly(gname):
    """The Pallas path (interpret) and its jnp twin are bit-identical."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=128, tile_size=64)
    r_x = skipper_match(schedule=s, backend="xla")
    r_p = skipper_match(schedule=s, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(r_x.match_mask), np.asarray(r_p.match_mask))
    np.testing.assert_array_equal(np.asarray(r_x.state), np.asarray(r_p.state))


def test_pipeline_counters_on_device():
    g = grid_graph(20, 20)
    res = skipper_match(g, window=128, tile_size=64, backend="xla")
    m = g.canonical().num_edges
    assert int(res.counters.edge_reads) == m
    assert int(res.counters.state_stores) == 2 * int(res.num_matches)
    assert int(res.counters.state_loads) >= 2 * m


# ------------------------------------------------ single-trace guarantee ---
def test_pipeline_single_trace_covers_all_windows():
    """Zero per-window host round-trips: one pipeline compilation regardless
    of window count, and repeated calls with the same static shapes do not
    retrace."""
    g = grid_graph(40, 40)  # 1600 vertices -> 13 windows of 128
    s = build_window_schedule(g, window=128, tile_size=64)
    assert s.num_windows > 8
    before = pipeline_trace_count()
    skipper_match(schedule=s, backend="xla", vector_rounds=2)
    after_first = pipeline_trace_count()
    assert after_first == before + 1, "expected exactly ONE trace for all windows"
    skipper_match(schedule=s, backend="xla", vector_rounds=2)
    assert pipeline_trace_count() == after_first, "retraced on identical shapes"


# ------------------------------------------------------ partition fix -----
def test_contiguous_chunks_returns_device_arrays():
    g = ring_graph(100)
    u, v = contiguous_chunks(g, 4)
    assert isinstance(u, jnp.ndarray) and isinstance(v, jnp.ndarray)
    assert u.shape == v.shape == (4, 25)
    np.testing.assert_array_equal(np.asarray(u).reshape(-1), np.asarray(g.u))


def test_contiguous_chunks_pads_with_invalid():
    g = EdgeList(jnp.asarray([0, 1, 2], jnp.int32), jnp.asarray([1, 2, 3], jnp.int32), 4)
    u, v = contiguous_chunks(g, 2)
    assert u.shape == (2, 2)
    assert int(u[-1, -1]) == -1 and int(v[-1, -1]) == -1
