"""Device-resident window pipeline: schedule correctness, locality
reordering, two-tier coalescing, matching properties across generator
families, backend equivalence (incl. the Pallas boundary epilogue), and the
zero-host-round-trip guarantee (single trace covers all windows)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import assert_matching, skipper
from repro.graphs import (
    EdgeList, bipartite_graph, erdos_renyi_graph, grid_graph, ring_graph,
    rmat_graph, star_graph, build_window_schedule, contiguous_chunks,
    intra_window_fraction, reorder_vertices,
)
from repro.kernels.skipper_match import skipper_match, pipeline_trace_count

GRAPHS = {
    "rmat": lambda: rmat_graph(10, 8, seed=3),
    "grid": lambda: grid_graph(24, 24),
    "ring": lambda: ring_graph(333),
    "star": lambda: star_graph(200),
    "bipartite": lambda: bipartite_graph(300, 200, 1500, seed=4),
}


# --------------------------------------------------- schedule invariants ---
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("window,tile", [(128, 64), (256, 128)])
def test_schedule_partitions_stream(gname, window, tile):
    """Every valid edge lands in exactly one slot (windowed or boundary);
    local ids are in-range; padding is -1."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=window, tile_size=tile)
    u = np.asarray(g.canonical().u)
    v = np.asarray(g.canonical().v)
    valid = (u >= 0) & (u != v)

    widx = s.edge_index[s.edge_index >= 0]
    bidx = s.boundary_index[s.boundary_index >= 0]
    both = np.concatenate([widx, bidx])
    assert len(both) == len(set(both.tolist())), "edge scheduled twice"
    np.testing.assert_array_equal(np.sort(both), np.nonzero(valid)[0])

    present = s.edge_index >= 0
    assert np.all(s.u_tiles[present] >= 0) and np.all(s.u_tiles[present] < window)
    assert np.all(s.v_tiles[present] >= 0) and np.all(s.v_tiles[present] < window)
    assert np.all(s.u_tiles[~present] == -1) and np.all(s.v_tiles[~present] == -1)
    # slot local ids reconstruct the original global endpoints (rows hold the
    # dense windows only; window_ids maps row -> window id)
    assert s.num_rows == len(s.window_ids) <= s.num_windows
    wrow = np.repeat(
        s.window_ids.astype(np.int64), s.tiles_per_window * s.tile_size
    ).reshape(s.num_rows, -1)
    np.testing.assert_array_equal(
        s.u_tiles[present] + wrow[present] * window, u[s.edge_index[present]]
    )
    np.testing.assert_array_equal(
        s.v_tiles[present] + wrow[present] * window, v[s.edge_index[present]]
    )


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_schedule_index_roundtrip(gname):
    """stream position <-> (window, tile, lane) round-trips exactly."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=128, tile_size=64)
    s2s = s.slot_to_stream()                 # [W, T, L] -> stream
    inv = s.stream_to_slot()                 # stream -> (w, t, l)
    w, t, l = np.nonzero(s2s >= 0)
    np.testing.assert_array_equal(inv[s2s[w, t, l]], np.stack([w, t, l], axis=1))
    # and the reverse: every scheduled stream position points back at its slot
    k = np.nonzero(inv[:, 0] >= 0)[0]
    wk, tk, lk = inv[k, 0], inv[k, 1], inv[k, 2]
    np.testing.assert_array_equal(s2s[wk, tk, lk], k)


def test_dispersed_deal_within_window():
    """Lane l of tile t holds window-stream slot l * tiles_per_window + t."""
    g = ring_graph(256)  # one window, edges in stream order
    s = build_window_schedule(g, window=256, tile_size=64)
    assert s.num_windows == 1
    s2s = s.slot_to_stream()[0]  # [tiles, lanes]
    tiles = s.tiles_per_window
    for t in range(tiles):
        for l in range(0, 64, 17):
            want = l * tiles + t
            got = s2s[t, l]
            if want < s.num_edges:
                assert got == want
            else:
                assert got == -1


# ------------------------------------------------- matching properties ----
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("window,tile", [(128, 64), (256, 128), (512, 64)])
def test_pipeline_valid_maximal_all_families(gname, window, tile):
    g = GRAPHS[gname]()
    res = skipper_match(g, window=window, tile_size=tile, backend="xla")
    out = assert_matching(g, res.match_mask, f"pipeline/{gname}/w{window}t{tile}")
    # any two maximal matchings are within 2x of each other
    ref, _ = skipper(g, tile_size=128)
    nref = int(ref.num_matches)
    assert nref / 2 <= out["num_matches"] <= 2 * nref


@pytest.mark.parametrize("gname", ["grid", "rmat", "star"])
def test_pipeline_pallas_interpret_matches_xla_exactly(gname):
    """The Pallas path (interpret) and its jnp twin are bit-identical."""
    g = GRAPHS[gname]()
    s = build_window_schedule(g, window=128, tile_size=64)
    r_x = skipper_match(schedule=s, backend="xla")
    r_p = skipper_match(schedule=s, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(r_x.match_mask), np.asarray(r_p.match_mask))
    np.testing.assert_array_equal(np.asarray(r_x.state), np.asarray(r_p.state))


def test_pipeline_counters_on_device():
    g = grid_graph(20, 20)
    res = skipper_match(g, window=128, tile_size=64, backend="xla")
    m = g.canonical().num_edges
    assert int(res.counters.edge_reads) == m
    assert int(res.counters.state_stores) == 2 * int(res.num_matches)
    assert int(res.counters.state_loads) >= 2 * m


# ------------------------------------------------ single-trace guarantee ---
def test_pipeline_single_trace_covers_all_windows():
    """Zero per-window host round-trips: one pipeline compilation regardless
    of window count, and repeated calls with the same static shapes do not
    retrace."""
    g = grid_graph(40, 40)  # 1600 vertices -> 13 windows of 128
    s = build_window_schedule(g, window=128, tile_size=64)
    assert s.num_windows > 8
    before = pipeline_trace_count()
    skipper_match(schedule=s, backend="xla", vector_rounds=2)
    after_first = pipeline_trace_count()
    assert after_first == before + 1, "expected exactly ONE trace for all windows"
    skipper_match(schedule=s, backend="xla", vector_rounds=2)
    assert pipeline_trace_count() == after_first, "retraced on identical shapes"


# -------------------------------------------------- locality reordering ---
POLICIES = ("degree", "bfs", "greedy")


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_reorder_perm_inverse_roundtrip(gname, policy):
    g = GRAPHS[gname]()
    r = reorder_vertices(g, policy, window=128)
    n = g.num_vertices
    ident = np.arange(n)
    np.testing.assert_array_equal(r.perm[r.inv], ident)
    np.testing.assert_array_equal(r.inv[r.perm], ident)
    # a bijection over exactly [0, n)
    assert sorted(r.perm.tolist()) == list(range(n))


def test_reorder_improves_rmat_locality():
    """The measured point of the subsystem: permuted RMAT's intra-window
    fraction rises to grid-like levels under every policy (rmat10 @ w=128
    mirrors the benched rmat14 @ w=2048 ratio n/window = 8)."""
    g = rmat_graph(10, 8, seed=3)
    base = intra_window_fraction(g, 128)
    for policy in POLICIES:
        r = reorder_vertices(g, policy, window=128)
        frac = intra_window_fraction(g, 128, r)
        assert frac > 2 * base, (policy, base, frac)
    s = build_window_schedule(g, window=128, tile_size=64, reorder="degree")
    assert s.intra_fraction == pytest.approx(
        intra_window_fraction(g, 128, reorder_vertices(g, "degree", 128))
    )


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_reorder_matching_valid_maximal_original_ids(gname, policy):
    """Renumbering must be invisible to callers: the mask is valid+maximal
    against the ORIGINAL graph and the state is in original vertex ids."""
    g = GRAPHS[gname]()
    res = skipper_match(g, window=128, tile_size=64, backend="xla",
                        reorder=policy)
    out = assert_matching(g, res.match_mask, f"reorder/{gname}/{policy}")
    e = g.canonical()
    u = np.asarray(e.u)
    v = np.asarray(e.v)
    mk = np.asarray(res.match_mask)
    st = np.asarray(res.state)
    assert np.all(st[u[mk]] == 2) and np.all(st[v[mk]] == 2)
    # matched count within the 2x maximal-matching band of the plain matcher
    ref, _ = skipper(g, tile_size=128)
    nref = int(ref.num_matches)
    assert nref / 2 <= out["num_matches"] <= 2 * nref


def test_reorder_schedule_roundtrip_through_perm():
    """Slot local ids reconstruct the RENUMBERED endpoints: local id +
    window_ids[row] * window == perm[original endpoint]."""
    g = rmat_graph(10, 8, seed=3)
    s = build_window_schedule(g, window=128, tile_size=64, reorder="degree")
    assert s.perm is not None and s.inv is not None
    u = np.asarray(g.canonical().u)
    v = np.asarray(g.canonical().v)
    present = s.edge_index >= 0
    wrow = np.repeat(
        s.window_ids.astype(np.int64), s.tiles_per_window * s.tile_size
    ).reshape(s.num_rows, -1)
    np.testing.assert_array_equal(
        s.u_tiles[present] + wrow[present] * s.window,
        s.perm[u[s.edge_index[present]]],
    )
    np.testing.assert_array_equal(
        s.v_tiles[present] + wrow[present] * s.window,
        s.perm[v[s.edge_index[present]]],
    )
    # and the boundary stream carries renumbered global ids
    b = s.boundary_index >= 0
    np.testing.assert_array_equal(s.boundary_u[b], s.perm[u[s.boundary_index[b]]])


def test_stream_src_gather_map_partitions_stream():
    """stream_src routes every valid edge to its decision slot and every
    invalid edge to the always-zero pad slot."""
    g = GRAPHS["bipartite"]()
    s = build_window_schedule(g, window=128, tile_size=64)
    slots = s.num_rows * s.tiles_per_window * s.tile_size
    pad_slot = slots + s.num_boundary_padded
    u = np.asarray(g.canonical().u)
    v = np.asarray(g.canonical().v)
    valid = (u >= 0) & (u != v)
    assert np.all(s.stream_src[~valid] == pad_slot)
    assert np.all(s.stream_src[valid] < pad_slot)
    # windowed slots point back at their edge_index entry
    widx = np.nonzero(s.edge_index.reshape(-1) >= 0)[0]
    np.testing.assert_array_equal(
        np.sort(s.stream_src[s.edge_index.reshape(-1)[widx]]), np.sort(widx)
    )


# ------------------------------------------------- two-tier coalescing ----
def test_two_tier_coalesces_sparse_windows():
    """A hub-heavy reordered graph compacts to few dense rows; sparse
    windows' intra edges move to the global tier; the matching stays
    valid+maximal and the padding accounting improves."""
    g = rmat_graph(10, 8, seed=3)
    dense_only = build_window_schedule(
        g, window=128, tile_size=64, reorder="degree", coalesce_sparse=False
    )
    two_tier = build_window_schedule(
        g, window=128, tile_size=64, reorder="degree", coalesce_sparse=True
    )
    assert two_tier.num_rows < dense_only.num_rows
    assert two_tier.num_windowed < two_tier.num_intra  # some edges coalesced
    assert two_tier.padding_waste < dense_only.padding_waste
    res = skipper_match(schedule=two_tier, backend="xla")
    assert_matching(g, res.match_mask, "two_tier/rmat")
    # both tiers partition the valid stream
    widx = two_tier.edge_index[two_tier.edge_index >= 0]
    bidx = two_tier.boundary_index[two_tier.boundary_index >= 0]
    both = np.concatenate([widx, bidx])
    u = np.asarray(g.canonical().u)
    v = np.asarray(g.canonical().v)
    np.testing.assert_array_equal(
        np.sort(both), np.nonzero((u >= 0) & (u != v))[0]
    )


def test_two_tier_balanced_graph_keeps_all_rows():
    """Balanced windows must not be coalesced (no false sparsity)."""
    g = grid_graph(24, 24)
    s = build_window_schedule(g, window=128, tile_size=64)
    occupied = np.unique(np.asarray(g.canonical().u) // 128)
    assert s.num_rows == len(occupied)


# ------------------------------------- Pallas boundary epilogue parity ----
@pytest.mark.parametrize("gname", ["er", "star", "rmat"])
def test_boundary_kernel_matches_jnp_reference_exactly(gname):
    """Backend equivalence for the boundary kernel: graphs dominated by
    global-tier (cross-window) edges decide identically on the Pallas
    epilogue (interpret) and the jnp tile_pass scan."""
    graphs = {
        "er": lambda: erdos_renyi_graph(600, 3000, seed=7),  # ~all boundary
        "star": lambda: star_graph(400),
        "rmat": lambda: rmat_graph(10, 8, seed=3),
    }
    g = graphs[gname]()
    s = build_window_schedule(g, window=128, tile_size=64)
    assert s.num_boundary_padded > 0, "want a non-trivial epilogue"
    r_x = skipper_match(schedule=s, backend="xla")
    r_p = skipper_match(schedule=s, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(r_x.match_mask), np.asarray(r_p.match_mask))
    np.testing.assert_array_equal(np.asarray(r_x.state), np.asarray(r_p.state))


def test_single_trace_with_pallas_boundary_epilogue():
    """The zero-host-round-trip proof holds for the full two-kernel pallas
    pipeline (windowed sweep + boundary epilogue in one compilation unit)."""
    g = grid_graph(24, 24)
    s = build_window_schedule(g, window=128, tile_size=64)
    assert s.num_boundary_padded > 0
    before = pipeline_trace_count()
    skipper_match(schedule=s, backend="pallas", interpret=True, vector_rounds=2)
    assert pipeline_trace_count() == before + 1
    skipper_match(schedule=s, backend="pallas", interpret=True, vector_rounds=2)
    assert pipeline_trace_count() == before + 1, "retraced on identical shapes"


# ------------------------------------------------------ partition fix -----
def test_contiguous_chunks_returns_device_arrays():
    g = ring_graph(100)
    u, v = contiguous_chunks(g, 4)
    assert isinstance(u, jnp.ndarray) and isinstance(v, jnp.ndarray)
    assert u.shape == v.shape == (4, 25)
    np.testing.assert_array_equal(np.asarray(u).reshape(-1), np.asarray(g.u))


def test_contiguous_chunks_pads_with_invalid():
    g = EdgeList(jnp.asarray([0, 1, 2], jnp.int32), jnp.asarray([1, 2, 3], jnp.int32), 4)
    u, v = contiguous_chunks(g, 2)
    assert u.shape == (2, 2)
    assert int(u[-1, -1]) == -1 and int(v[-1, -1]) == -1
