"""State-width policy (DESIGN.md §12, ``core/statespec.py``).

The refactor's contract has two halves and this module pins both:

1. **Width never changes decisions.** The engine compares state against
   plain ints and widens to i32 inside the one-hot gathers, so the uint8
   default and ``StateSpec.legacy_i32()`` (the exact pre-refactor i32
   graph) must produce bit-identical matchings through every entry point:
   ``skipper_match`` (both backends), ``skipper``, the distributed
   matcher (both schedules, D=1 in-process and forced D=4 in a
   subprocess, clean and under chaos), and ``bmatch_assign``.

2. **Narrowing is guarded, not silent.** ``validate_rounds`` refuses a
   conflict counter that could wrap; ``validate_capacity`` gates the
   capacitated used-count width; summing callers keep i32 accumulators
   (``StateSpec.accum`` is pinned to int32).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from strategies import (  # noqa: E402
    given,
    run_subprocess as _run_subprocess,
    settings,
    st,
)

from repro.core import assert_matching
from repro.core.bipartite import bmatch_assign
from repro.core.distributed import distributed_skipper
from repro.core.faults import FaultPlan
from repro.core.statespec import DEFAULT, StateSpec, resolve
from repro.core.validate import check_state_domain
from repro.graphs import erdos_renyi_graph, grid_graph, rmat_graph
from repro.graphs.types import EdgeList
from repro.graphs.windows import build_window_schedule
from repro.kernels.skipper_match import skipper_match

SPECS = {
    "u8": StateSpec.u8(),
    "legacy_i32": StateSpec.legacy_i32(),
}


# ---------------------------------------------------------------------------
# spec object: fields, guards, hashability
# ---------------------------------------------------------------------------

def test_default_is_single_byte_everywhere():
    assert DEFAULT == StateSpec.u8()
    assert (DEFAULT.at_rest_bytes, DEFAULT.vmem_bytes, DEFAULT.wire_bytes,
            DEFAULT.counter_bytes) == (1, 1, 1, 1)
    assert DEFAULT.combine == "max"
    # legacy keeps the paper's at-rest byte but i32 everywhere hot
    leg = StateSpec.legacy_i32()
    assert leg.at_rest_bytes == 1
    assert (leg.vmem_bytes, leg.wire_bytes, leg.counter_bytes) == (4, 4, 4)
    assert leg.combine == "psum"


def test_spec_is_frozen_and_cache_key_safe():
    s = StateSpec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.vmem = "int32"
    assert hash(StateSpec()) == hash(StateSpec.u8())
    assert StateSpec() != StateSpec.legacy_i32()
    assert len({StateSpec(), StateSpec.u8(), StateSpec.legacy_i32()}) == 2


def test_resolve_none_is_default():
    assert resolve(None) is DEFAULT
    leg = StateSpec.legacy_i32()
    assert resolve(leg) is leg


def test_invalid_fields_raise():
    with pytest.raises(ValueError, match="at_rest"):
        StateSpec(at_rest="float32")
    with pytest.raises(ValueError, match="combine"):
        StateSpec(combine="mean")
    with pytest.raises(ValueError, match="accum"):
        StateSpec(accum="uint8")


def test_validate_rounds_guard():
    StateSpec().validate_rounds(255)  # fits exactly
    with pytest.raises(ValueError, match="vector_rounds=300"):
        StateSpec().validate_rounds(300)
    StateSpec.legacy_i32().validate_rounds(300)  # i32 counter: fine


def test_validate_rounds_guard_fires_through_the_matcher():
    """An unholdable conflict counter must refuse to build, not wrap."""
    g = grid_graph(8, 8)
    with pytest.raises(ValueError, match="vector_rounds"):
        skipper_match(g, window=64, tile_size=64, backend="xla",
                      vector_rounds=300)
    # the wide counter accepts the same request
    r = skipper_match(g, window=64, tile_size=64, backend="xla",
                      vector_rounds=300, spec=StateSpec.legacy_i32())
    assert_matching(g, r.match_mask, "rounds300/legacy")


def test_validate_capacity():
    assert StateSpec().validate_capacity(255)
    assert not StateSpec().validate_capacity(256)
    assert StateSpec.legacy_i32().validate_capacity(255)


# ---------------------------------------------------------------------------
# equivalence matrix: single-device matchers
# ---------------------------------------------------------------------------

GRAPHS = [
    ("grid", lambda: grid_graph(16, 16)),
    ("rmat", lambda: rmat_graph(10, 8, seed=3)),
    ("er", lambda: erdos_renyi_graph(600, 2400, seed=7)),
]


@pytest.mark.parametrize("gname,gf", GRAPHS)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_skipper_match_bit_identical_across_specs(gname, gf, backend):
    g = gf()
    kw = dict(window=256, tile_size=256, reorder="degree", backend=backend)
    if backend == "pallas":
        kw["interpret"] = True
    base = skipper_match(g, **kw)
    for sname, spec in SPECS.items():
        r = skipper_match(g, spec=spec, **kw)
        assert bool(jnp.all(r.match_mask == base.match_mask)), (
            f"{gname}/{backend}/{sname}")
        # at-rest state is 1 B/vertex under BOTH blessed specs
        assert r.state.dtype == jnp.uint8
        assert bool(jnp.all(r.state == base.state))
        assert bool(check_state_domain(r.state)["clean"])
    assert_matching(g, base.match_mask, f"{gname}/{backend}")


def test_skipper_raw_stream_spec_equivalence():
    from repro.core.skipper import skipper

    g = rmat_graph(10, 8, seed=5)
    base, _ = skipper(g, tile_size=256)
    for sname, spec in SPECS.items():
        r, _ = skipper(g, tile_size=256, spec=spec)
        assert bool(jnp.all(r.match_mask == base.match_mask)), sname
        assert r.state.dtype == jnp.uint8  # at_rest in both blessed specs
    assert_matching(g, base.match_mask, "skipper/raw")


@given(
    edges=st.lists(
        st.tuples(st.integers(0, 49), st.integers(0, 49)),
        min_size=0, max_size=120,
    ),
    rounds=st.integers(1, 3),
)
@settings(max_examples=25, deadline=None)
def test_hypothesis_any_stream_spec_invariant(edges, rounds):
    """Random (self-loop/dup/isolated-heavy) streams: the u8 and legacy
    graphs decide every edge identically, and the result is a valid
    maximal matching."""
    n = 50
    u = np.array([e[0] for e in edges] + [-1], np.int32)
    v = np.array([e[1] for e in edges] + [-1], np.int32)
    g = EdgeList(u=u, v=v, num_vertices=n)
    masks = {}
    for sname, spec in SPECS.items():
        r = skipper_match(g, window=64, tile_size=64, backend="xla",
                          vector_rounds=rounds, spec=spec)
        masks[sname] = np.asarray(r.match_mask)
    assert (masks["u8"] == masks["legacy_i32"]).all()
    assert_matching(g, jnp.asarray(masks["u8"]), "hyp")


# ---------------------------------------------------------------------------
# distributed: D=1 in-process, chaos ladder, D=4 subprocess
# ---------------------------------------------------------------------------

def test_distributed_both_schedules_spec_equivalence():
    g = grid_graph(20, 20)
    for kw in (dict(block_size=256),                       # dispersed
               dict(block_size=256, reorder="degree", window=256)):
        base, bstats = distributed_skipper(g, **kw)
        leg, lstats = distributed_skipper(
            g, spec=StateSpec.legacy_i32(), **kw)
        assert bool(jnp.all(base.match_mask == leg.match_mask))
        assert base.state.dtype == jnp.uint8
        assert leg.state.dtype == jnp.uint8
        assert_matching(g, base.match_mask, f"dist/{sorted(kw)}")
        if "window" in kw:
            # PHASE A payload is counted at the wire width: the sharded
            # legacy run gathers exactly 3 more bytes per state cell
            d_bytes = int(lstats.gathered_bytes) - int(bstats.gathered_bytes)
            assert d_bytes > 0 and d_bytes % 3 == 0


def test_diststats_gathered_ints_alias_deprecated():
    g = grid_graph(12, 12)
    _, stats = distributed_skipper(g, block_size=256)
    with pytest.warns(DeprecationWarning, match="gathered_bytes"):
        gi = int(stats.gathered_ints)
    assert gi == int(stats.gathered_bytes) // 4


def test_diststats_gathered_ints_warns_exactly_once():
    """The alias is for EXTERNAL callers: under the default filter a
    caller site warns once, not once per access — and no internal code
    path touches the alias at all (also pinned by the analyzer's
    deprecated-alias rule), so a plain run warns zero times."""
    import warnings

    g = grid_graph(12, 12)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("default")
        _, stats = distributed_skipper(g, block_size=256)
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "gathered" in str(w.message)]

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("default")
        for _ in range(3):
            _ = stats.gathered_ints
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in dep]


def test_chaos_recover_spec_equivalence():
    """The recovery ladder under injected faults lands on the same
    valid+maximal matching at either width (same seeded victims, same
    mask-anchored replay)."""
    g = erdos_renyi_graph(800, 3200, seed=11)
    plan = FaultPlan(seed=5, drop_proposals=0.2, corrupt_state=0.01)
    masks = {}
    for sname, spec in SPECS.items():
        r, stats = distributed_skipper(
            g, block_size=256, reorder="degree", window=256,
            faults=plan, on_fault="recover", verify=True, spec=spec,
        )
        masks[sname] = np.asarray(r.match_mask)
        assert bool(check_state_domain(r.state)["clean"])
    assert (masks["u8"] == masks["legacy_i32"]).all()


_SUBPROCESS_MATRIX = r"""
import jax, numpy as np
import jax.numpy as jnp
assert jax.device_count() == 4
from repro.core import assert_matching
from repro.core.distributed import distributed_skipper
from repro.core.statespec import StateSpec
from repro.graphs import erdos_renyi_graph

g = erdos_renyi_graph(1200, 4800, seed=13)
for kw in (dict(block_size=256),
           dict(block_size=256, reorder="degree", window=256)):
    base, bs = distributed_skipper(g, **kw)
    leg, ls = distributed_skipper(g, spec=StateSpec.legacy_i32(), **kw)
    assert bool(jnp.all(base.match_mask == leg.match_mask)), kw
    assert base.state.dtype == jnp.uint8
    assert_matching(g, base.match_mask, f"d4/{sorted(kw)}")
    if "window" in kw:
        assert int(ls.gathered_bytes) > int(bs.gathered_bytes)
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_spec_equivalence_forced_4_devices():
    """u8 max-combine == legacy i32 psum across a real 4-way shard_map:
    the disjoint-rows argument for the width-honest combine, executed."""
    _run_subprocess(_SUBPROCESS_MATRIX, num_devices=4)


# ---------------------------------------------------------------------------
# capacitated adapter
# ---------------------------------------------------------------------------

def test_bmatch_spec_equivalence():
    rng = np.random.default_rng(3)
    m, nt, ne = 4096, 256, 32
    tok = rng.integers(0, nt, m).astype(np.int32)
    exp = rng.integers(0, ne, m).astype(np.int32)
    tok[rng.random(m) < 0.05] = -1  # invalid candidates
    kw = dict(num_tokens=nt, num_experts=ne, token_budget=2,
              expert_capacity=24, tile_size=512)
    base, bstats = bmatch_assign(
        jnp.asarray(tok), jnp.asarray(exp), with_stats=True, **kw)
    for sname, spec in SPECS.items():
        acc, stats = bmatch_assign(
            jnp.asarray(tok), jnp.asarray(exp), with_stats=True,
            spec=spec, **kw)
        assert bool(jnp.all(acc == base.astype(acc.dtype))), sname
        assert int(stats["conflicts"]) == int(bstats["conflicts"])


def test_bmatch_wide_capacity_falls_back_to_accum():
    """expert_capacity > 255 cannot live in a u8 used count — the adapter
    must widen, not wrap: with 300 slots on one expert, all 300 accepted."""
    m = 512
    tok = jnp.arange(m, dtype=jnp.int32)
    exp = jnp.zeros((m,), jnp.int32)
    acc = bmatch_assign(
        tok, exp, num_tokens=m, num_experts=1, token_budget=1,
        expert_capacity=300, tile_size=512,
    )
    assert int(jnp.sum(acc)) == 300


# ---------------------------------------------------------------------------
# validators / instrumentation
# ---------------------------------------------------------------------------

def test_check_state_domain_any_width():
    for dt in (jnp.uint8, jnp.int32):
        clean = jnp.asarray([0, 2, 0, 2], dt)
        out = check_state_domain(clean)
        assert bool(out["clean"])
        dirty = jnp.asarray([0, 7, 1, 2], dt)
        out = check_state_domain(dirty)
        assert not bool(out["clean"])
        assert int(out["out_of_domain"]) == 1
        assert int(out["rsvd_leaked"]) == 1


def test_roofline_state_traffic_scales_with_spec():
    from repro.roofline.analysis import state_traffic_bytes

    g = grid_graph(16, 16)
    r = skipper_match(g, window=256, tile_size=256, backend="xla")
    u8 = state_traffic_bytes(r.counters)
    i32 = state_traffic_bytes(r.counters, StateSpec.legacy_i32())
    assert u8["state_bytes"] * 4 == i32["state_bytes"]
    assert u8["edge_bytes"] == i32["edge_bytes"]  # topology stays i32
    assert u8["total_bytes"] < i32["total_bytes"]


def test_window_schedule_byte_helpers():
    g = rmat_graph(10, 8, seed=3)
    s = build_window_schedule(g, window=256, tile_size=256, reorder="degree")
    leg = StateSpec.legacy_i32()
    assert s.vmem_state_bytes() * 4 == s.vmem_state_bytes(leg)
    assert s.wire_state_bytes(num_devices=4) * 4 == s.wire_state_bytes(
        leg, num_devices=4)
    assert s.wire_state_bytes(num_devices=4) == (
        4 * s.num_rows * s.window * 1)
