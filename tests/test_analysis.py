"""Kernel conformance analyzer (``src/repro/analysis/``, DESIGN.md §14).

Four pins:

1. **Rules discriminate.** Every rule has a minimal passing fixture and a
   minimal violating fixture — a rule that flags the good case or misses
   the bad case is broken in itself, independent of the production tree.
2. **Mutation canaries.** Each seeded mutant of the boundary kernel
   (``analysis/mutations.py``) is caught by the EXPECTED rule — the
   analyzer keeps its teeth against exactly the hazard classes the
   ROADMAP listed as "verify on silicon".
3. **The clean tree is clean.** Source battery over ``src/repro`` plus
   the kernel targets analyze to zero errors (the full 9-target sweep is
   the CI ``static-analysis`` job; here we keep the fast subset so tier-1
   stays quick).
4. **Recompile guard.** Repeated ``skipper_match`` / ``distributed_skipper``
   calls with equal configs hit the lru-cached builders (the PR 3/PR 5
   caching fixes), observed via ``cache_info`` — a regression that
   re-traces per call shows up as zero hits.
"""
import types

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import Severity, analyze_mutation, analyze_sources
from repro.analysis.mutations import MUTATION_NAMES
from repro.analysis.rules.base import SourceFile, get_rules
from repro.analysis.rules.deprecated_alias import DeprecatedAlias
from repro.analysis.rules.dma_order import DmaHappensBefore, WritebackOrder
from repro.analysis.rules.host_sync import HostSync, LruStaticKey, TracedCallback
from repro.analysis.rules.mosaic_lowering import MosaicGather
from repro.analysis.rules.state_dtype import StateDtype
from repro.analysis.rules.vmem_budget import (
    BlockRace,
    PallasCount,
    TileGeometry,
    VmemBudget,
)
from repro.analysis.targets import get_targets
from repro.analysis.trace import collect_pallas_calls
from repro.graphs import grid_graph


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _boundary_artifact():
    (target,) = get_targets(["boundary_kernel"])
    (art,) = collect_pallas_calls(target.trace(1), target.name)
    return target, art


def _mutant_artifact(name):
    from repro.analysis.mutations import trace_kernel_mutation

    (art,) = collect_pallas_calls(trace_kernel_mutation(name), f"m:{name}")
    return art


def _tiny_call(lane, dtype=jnp.uint8, out_map=None, grid=(2, 2)):
    """Minimal synthetic pallas_call for geometry / race fixtures."""
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    out_map = out_map or (lambda i, j: (i, 0))
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8, lane), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((8, lane), out_map),
        out_shape=jax.ShapeDtypeStruct((8 * grid[0], lane), dtype),
        interpret=True,
    )
    x = jax.ShapeDtypeStruct((8 * grid[0], lane), dtype)
    return jax.make_jaxpr(call)(x)


def _src(text, path="src/repro/fake_mod.py"):
    return SourceFile.parse(path, text)


# ---------------------------------------------------------------------------
# 1. rule discrimination: one passing + one violating fixture per rule
# ---------------------------------------------------------------------------

def test_mosaic_gather_rule():
    _, good = _boundary_artifact()
    assert MosaicGather().check_kernel(good) == []
    bad = _mutant_artifact("dynamic_gather")
    hits = MosaicGather().check_kernel(bad)
    assert hits and all(f.severity is Severity.ERROR for f in hits)
    assert "gather" in hits[0].message


def test_dma_happens_before_rule():
    _, good = _boundary_artifact()
    assert DmaHappensBefore().check_kernel(good) == []
    bad = _mutant_artifact("dropped_dma_wait")
    hits = DmaHappensBefore().check_kernel(bad)
    assert [f.severity for f in hits] == [Severity.ERROR]
    assert "unwaited" in hits[0].message


def test_writeback_order_rule():
    _, good = _boundary_artifact()
    assert WritebackOrder().check_kernel(good) == []
    bad = _mutant_artifact("swapped_writeback")
    hits = WritebackOrder().check_kernel(bad)
    assert [f.severity for f in hits] == [Severity.ERROR]
    # the windowed kernels have no aliased ANY state: rule not applicable
    (pt,) = get_targets(["pipeline_kernel"])
    (pa,) = collect_pallas_calls(pt.trace(1), pt.name)
    assert WritebackOrder().check_kernel(pa) == []


def test_tile_geometry_rule():
    ok = collect_pallas_calls(_tiny_call(lane=128), "t")[0]
    assert not [f for f in TileGeometry().check_kernel(ok)
                if f.severity is Severity.ERROR]
    bad = collect_pallas_calls(_tiny_call(lane=64), "t")[0]
    hits = [f for f in TileGeometry().check_kernel(bad)
            if f.severity is Severity.ERROR]
    assert hits and "128" in hits[0].message  # uint8 lane misalignment


def test_block_race_rule():
    rule = BlockRace()
    tgt = types.SimpleNamespace(name="t")
    ok = _tiny_call(lane=128, out_map=lambda i, j: (i, 0))
    arts = collect_pallas_calls(ok, "t")
    assert not [f for f in rule.check_target(tgt, ok, arts)
                if f.severity is Severity.ERROR]
    # block revisited at non-consecutive grid steps: (i,j) -> (j, 0) under
    # row-major iteration visits block 0 at steps 0 and 2
    bad = _tiny_call(lane=128, out_map=lambda i, j: (j, 0))
    arts = collect_pallas_calls(bad, "t")
    hits = [f for f in rule.check_target(tgt, bad, arts)
            if f.severity is Severity.ERROR]
    assert hits and "non-consecutive" in hits[0].message


def test_vmem_budget_rule_detects_v_dependence():
    rule = VmemBudget()

    def build(scale):
        return _tiny_call(lane=128 * scale, grid=(2, 1))

    leaky = types.SimpleNamespace(
        name="leaky", rescalable=True, vmem_claim="", trace=build,
    )
    arts = collect_pallas_calls(build(1), "leaky")
    hits = [f for f in rule.check_target(leaky, build(1), arts)
            if f.severity is Severity.ERROR]
    assert hits and "V-independence claim is broken" in hits[0].message
    # the real boundary target passes (V-independence verified as INFO)
    target, art = _boundary_artifact()
    infos = rule.check_target(target, target.trace(1), [art])
    assert not [f for f in infos if f.severity is Severity.ERROR]
    assert any("V-independence verified" in f.message for f in infos)


def test_pallas_count_rule():
    rule = PallasCount()
    tgt = types.SimpleNamespace(name="t", expect_pallas=1)
    jx = _tiny_call(lane=128)
    arts = collect_pallas_calls(jx, "t")
    assert not [f for f in rule.check_target(tgt, jx, arts)
                if f.severity is Severity.ERROR]
    hits = rule.check_target(
        types.SimpleNamespace(name="t", expect_pallas=2), jx, arts,
    )
    assert [f.severity for f in hits] == [Severity.ERROR]


def test_traced_callback_rule():
    rule = TracedCallback()
    tgt = types.SimpleNamespace(name="t")
    clean = jax.make_jaxpr(lambda x: x * 2)(jnp.ones((4,)))
    assert rule.check_target(tgt, clean, []) == []

    def with_cb(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x,
        )

    dirty = jax.make_jaxpr(with_cb)(jnp.ones((4,)))
    hits = rule.check_target(tgt, dirty, [])
    assert hits and hits[0].severity is Severity.ERROR


def test_state_dtype_rule():
    rule = StateDtype()
    assert rule.check_file(_src(
        "import jax.numpy as jnp\n"
        "def f(spec, n):\n"
        "    state = jnp.zeros((n,), spec.vmem_dtype)\n"
        "    ids = jnp.zeros((n,), jnp.int32)\n"   # not state-ish: fine
        "    return state, ids\n"
    )) == []
    hits = rule.check_file(_src(
        "import jax.numpy as jnp\n"
        "def f(n):\n"
        "    state = jnp.zeros((n,), jnp.int32)\n"
        "    return state\n"
    ))
    assert [f.severity for f in hits] == [Severity.ERROR]
    # waiver silences the same line
    assert rule.check_file(_src(
        "import jax.numpy as jnp\n"
        "def f(n):\n"
        "    state = jnp.zeros((n,), jnp.int32)  # state-dtype: ok\n"
        "    return state\n"
    )) == []


def test_host_sync_rule():
    rule = HostSync()
    assert rule.check_file(_src(
        "import jax\n"
        "def f(x):\n"
        "    return jax.device_get(x)  # host-sync: ok (documented)\n"
    )) == []
    hits = rule.check_file(_src(
        "import jax\n"
        "def f(x):\n"
        "    return jax.device_get(x)\n"
    ))
    assert [f.severity for f in hits] == [Severity.ERROR]
    # out-of-library drivers (benchmarks/) fetch freely
    assert rule.check_file(_src(
        "import jax\ndef f(x):\n    return jax.device_get(x)\n",
        path="benchmarks/bench_thing.py",
    )) == []


def test_lru_static_key_rule():
    rule = LruStaticKey()
    assert rule.check_file(_src(
        "import functools\n"
        "@functools.lru_cache(maxsize=None)\n"
        "def build(n, tile, spec=None):\n"
        "    return n\n"
    )) == []
    hits = rule.check_file(_src(
        "import functools\n"
        "@functools.lru_cache(maxsize=None)\n"
        "def build(n, opts=[]):\n"
        "    return n\n"
    ))
    assert [f.severity for f in hits] == [Severity.ERROR]


def test_deprecated_alias_rule():
    rule = DeprecatedAlias()
    assert rule.check_file(_src(
        "def f(stats):\n    return stats.gathered_bytes\n"
    )) == []
    hits = rule.check_file(_src(
        "def f(stats):\n    return stats.gathered_ints\n"
    ))
    assert [f.severity for f in hits] == [Severity.ERROR]
    # the definition site and tests are exempt
    assert rule.check_file(_src(
        "def f(s):\n    return s.gathered_ints\n",
        path="src/repro/core/distributed.py",
    )) == []
    assert rule.check_file(_src(
        "def f(s):\n    return s.gathered_ints\n",
        path="tests/test_statespec.py",
    )) == []


# ---------------------------------------------------------------------------
# 2. mutation canaries: each mutant caught by the EXPECTED rule
# ---------------------------------------------------------------------------

EXPECTED_RULE = {
    "dropped_dma_wait": "dma-happens-before",
    "swapped_writeback": "writeback-order",
    "dynamic_gather": "mosaic-gather",
    "hardcoded_state_dtype": "state-dtype",
}


@pytest.mark.parametrize("name", sorted(EXPECTED_RULE))
def test_mutation_canary_caught(name):
    report = analyze_mutation(name)
    assert not report.clean, f"mutant {name} analyzed clean: teeth lost"
    assert EXPECTED_RULE[name] in {f.rule for f in report.errors}


def test_mutation_registry_complete():
    assert sorted(MUTATION_NAMES) == sorted(EXPECTED_RULE)
    with pytest.raises(KeyError):
        analyze_mutation("no_such_mutation")


# ---------------------------------------------------------------------------
# 3. the clean tree is clean (fast subset; full sweep runs in CI)
# ---------------------------------------------------------------------------

def test_clean_tree_sources():
    report = analyze_sources(["src/repro", "benchmarks", "examples"])
    assert report.clean, report.render()
    assert report.files_analyzed > 50


def test_clean_kernel_targets():
    from repro.analysis.runner import analyze_targets

    report = analyze_targets(
        ["window_kernel", "pipeline_kernel", "boundary_kernel",
         "flash_attention"]
    )
    assert report.clean, report.render()
    assert len(report.targets_analyzed) == 4
    # the budget measurements land in the JSON next to the roofline numbers
    d = report.to_dict()
    assert d["version"] == 1 and d["clean"]
    budgets = [f for f in report.findings
               if f.rule == "vmem-budget" and f.data
               and "total_bytes" in f.data]
    assert budgets


def test_roofline_vmem_hook():
    from repro.roofline import vmem_step_bytes

    out = vmem_step_bytes("boundary_kernel")
    assert out["skipper_boundary_kernel"]["total_bytes"] > 0


# ---------------------------------------------------------------------------
# 4. recompile guard: equal configs must hit the cached builders
# ---------------------------------------------------------------------------

def test_skipper_match_recompile_guard():
    from repro.kernels.skipper_match import ops, skipper_match

    g = grid_graph(16, 16)
    kw = dict(window=256, tile_size=256)
    skipper_match(g, **kw)
    before = ops._build_pipeline.cache_info()
    skipper_match(g, **kw)
    after = ops._build_pipeline.cache_info()
    assert after.hits > before.hits, (
        f"equal-config skipper_match re-traced: {before} -> {after}"
    )


def test_distributed_skipper_recompile_guard():
    from repro.core import distributed
    from repro.core.distributed import distributed_skipper

    g = grid_graph(16, 16)
    distributed_skipper(g, block_size=256)
    before = distributed._compiled_dispersed.cache_info()
    distributed_skipper(g, block_size=256)
    after = distributed._compiled_dispersed.cache_info()
    assert after.hits > before.hits

    distributed_skipper(g, block_size=256, window=256, reorder="none")
    before = distributed._compiled_sharded.cache_info()
    distributed_skipper(g, block_size=256, window=256, reorder="none")
    after = distributed._compiled_sharded.cache_info()
    assert after.hits > before.hits
