"""Distributed Skipper: protocol correctness on 1 device in-process and on
forced host devices (D in {2, 4, 8}) in subprocesses (so the main pytest
process keeps its single-device jax).

Covers both schedules:

* dispersed (raw stream blocks, paper §IV-C) — including the D=1
  sequential-greedy equivalence (the tile fallback's fixpoint is the
  index-order greedy, so one device scanning the stream IS sgmm);
* locality-sharded (window-aware partitioning) — including the pinned
  bit-identity of D=1 against ``skipper_match`` on the same schedule, and
  the D-invariance of the window tier (windows are disjoint vertex ranges,
  so a window's decisions don't depend on which device ran it).

Plus the must-be-zero invariant enforcement (retry_overflow / undrained
raise), the vector_rounds matching-invariance, and the real-work counter
accounting (padded sentinel slots scanned during drain rounds count
nothing).
"""
import numpy as np
import pytest

from strategies import run_subprocess as _run_subprocess  # noqa: E402

from repro.core import assert_matching, sgmm
from repro.core.distributed import distributed_skipper
from repro.graphs import (
    erdos_renyi_graph,
    grid_graph,
    rmat_graph,
    star_graph,
)
from repro.kernels.skipper_match import skipper_match

POLICIES = ("degree", "bfs", "greedy")


@pytest.mark.parametrize("gname,g", [
    ("grid", grid_graph(20, 20)),
    ("er", erdos_renyi_graph(2000, 8000, seed=9)),
    ("star", star_graph(150)),
])
def test_distributed_single_device(gname, g):
    result, stats = distributed_skipper(g, block_size=128)
    assert_matching(g, result.match_mask, f"dist1/{gname}")
    assert stats.ok
    assert int(stats.retry_overflow) == 0
    assert int(stats.undrained) == 0
    # one device -> no cross-device conflicts possible
    assert int(stats.lost_proposals) == 0


def test_dispersed_single_device_is_sequential_greedy():
    """D=1 dispersed == sgmm on the stream: the tile fallback's fixpoint is
    the index-order greedy and blocks arrive in stream order."""
    for gname, g in [
        ("rmat", rmat_graph(11, 16, seed=6)),
        ("grid", grid_graph(20, 20)),
        ("er", erdos_renyi_graph(2000, 8000, seed=9)),
    ]:
        r, _ = distributed_skipper(g, block_size=256)
        ms = sgmm(g)
        assert bool(
            (np.asarray(r.match_mask) == np.asarray(ms.match_mask)).all()
        ), gname


@pytest.mark.parametrize("policy", POLICIES)
def test_sharded_single_device_bit_identical_to_skipper_match(policy):
    """Pinned: D=1 locality-sharded == skipper_match on the same schedule —
    mask AND state, original ids."""
    for gname, g in [
        ("rmat11", rmat_graph(11, 16, seed=6)),
        ("grid", grid_graph(30, 30)),
        ("star", star_graph(400)),
    ]:
        rd, stats = distributed_skipper(
            g, block_size=512, tile_size=256, window=1024, reorder=policy
        )
        rk = skipper_match(g, window=1024, tile_size=256, reorder=policy,
                           backend="xla")
        assert bool(
            (np.asarray(rd.match_mask) == np.asarray(rk.match_mask)).all()
        ), (policy, gname)
        assert bool((np.asarray(rd.state) == np.asarray(rk.state)).all()), (
            policy, gname)
        assert_matching(g, rd.match_mask, f"sharded1/{policy}/{gname}")
        assert stats.ok


@pytest.mark.parametrize("sharded", [False, True])
def test_vector_rounds_never_change_the_matching(sharded):
    """Extra unrolled rounds are pure instrumentation tuning: the exact
    fallback makes the matching invariant (only conflict-derived counters
    may move)."""
    g = erdos_renyi_graph(2000, 8000, seed=9)
    kw = dict(reorder="degree") if sharded else dict(block_size=256)
    r1, _ = distributed_skipper(g, vector_rounds=1, **kw)
    r3, _ = distributed_skipper(g, vector_rounds=3, **kw)
    assert bool(
        (np.asarray(r1.match_mask) == np.asarray(r3.match_mask)).all()
    )
    assert bool((np.asarray(r1.state) == np.asarray(r3.state)).all())


@pytest.mark.parametrize("sharded", [False, True])
def test_counters_count_only_real_edge_work(sharded):
    """Drain rounds scan sentinel-padded slabs; none of it may leak into the
    work counters. reads == valid edges + requeue re-scans, exactly."""
    g = erdos_renyi_graph(2000, 8000, seed=9)
    u, v = np.asarray(g.u), np.asarray(g.v)
    m_valid = int(((u >= 0) & (u != v)).sum())
    kw = dict(reorder="degree") if sharded else dict(block_size=256)
    ra, sa = distributed_skipper(g, drain_rounds=2, **kw)
    rb, sb = distributed_skipper(g, drain_rounds=8, **kw)
    for f in ("edge_reads", "state_loads", "state_stores"):
        assert int(getattr(ra.counters, f)) == int(getattr(rb.counters, f)), f
    assert int(ra.counters.edge_reads) == m_valid + int(sa.requeued)
    assert int(ra.counters.state_stores) == 2 * int(ra.num_matches)


# --- must-be-zero invariant enforcement (retry overflow / undrained) -----

# A fan construction that forces the D=2 retry buffer over capacity with
# block_size=tile_size=8 (see the round-by-round walkthrough in the git
# history of this test): round 0 requeues the (c, x_i) fan behind a losing
# provisional claim, round 1 requeues the fan AND the fresh (c, y_i) block
# behind the retried (c, x1) — 13 entries into an 8-slot buffer.
_OVERFLOW_SCRIPT = r"""
import numpy as np, jax
import jax.numpy as jnp
assert len(jax.devices()) == 2
from repro.graphs.types import EdgeList
from repro.core.distributed import distributed_skipper

a, b, h, x1, w, tt, c = 0, 1, 2, 3, 4, 5, 6
x = [3, 7, 8, 9, 10, 11]
y = list(range(12, 20))
dum = iter(range(20, 60, 2))
def d():
    p = next(dum)
    return (p, p + 1)
blocks = [
    [(a, b), (h, x1), (x1, w)] + [d() for _ in range(5)],   # b0 -> dev0 r0
    [(h, tt), (a, c)] + [(c, xi) for xi in x],              # b1 -> dev1 r0
    [d() for _ in range(8)],                                # b2 -> dev0 r1
    [(c, yi) for yi in y],                                  # b3 -> dev1 r1
]
eu = np.array([e[0] for blk in blocks for e in blk], np.int32)
ev = np.array([e[1] for blk in blocks for e in blk], np.int32)
g = EdgeList(jnp.asarray(eu), jnp.asarray(ev), 60)

# default on_fault="raise" raises on the violated invariant
try:
    distributed_skipper(g, block_size=8, tile_size=8)
    raise SystemExit("expected RuntimeError on retry overflow")
except RuntimeError as e:
    assert "retry_overflow" in str(e), e

# on_fault="report" surfaces the numbers instead
r, st = distributed_skipper(g, block_size=8, tile_size=8, on_fault="report")
assert int(st.retry_overflow) == 5, int(st.retry_overflow)
assert not st.ok

# tiny drain_rounds additionally leaves the buffer undrained
r, st = distributed_skipper(
    g, block_size=8, tile_size=8, drain_rounds=0, on_fault="report"
)
assert int(st.retry_overflow) == 5
assert int(st.undrained) == 8, int(st.undrained)
assert not st.ok

# a big-enough buffer clears both invariants on the same graph
r, st = distributed_skipper(g, block_size=32, tile_size=8)
assert st.ok

# on_fault="recover": the in-protocol escalation regrows the retry buffer
# (8 -> 16 -> 32) until the same graph clears, no replay rung needed
r, st = distributed_skipper(
    g, block_size=8, tile_size=8, on_fault="recover", verify=True
)
assert int(st.retry_overflow) == 0 and int(st.undrained) == 0
assert int(st.recovery_attempts) >= 1, int(st.recovery_attempts)
assert int(st.residual_edges) == 0, int(st.residual_edges)
print("SUBPROCESS_OK")
"""


@pytest.mark.subprocess
def test_retry_overflow_and_undrained_raise():
    _run_subprocess(_OVERFLOW_SCRIPT, num_devices=2)


# --- multi-device equivalence matrix -------------------------------------

_EQUIV_SCRIPT_TEMPLATE = r"""
import jax
assert len(jax.devices()) == {D}, jax.devices()
import numpy as np
from repro.graphs import (rmat_graph, grid_graph, erdos_renyi_graph,
                          path_graph, build_window_schedule)
from repro.core.distributed import distributed_skipper
from repro.core import assert_matching, sgmm
from repro.kernels.skipper_match import skipper_match

D = {D}
for policy in ("degree", "bfs", "greedy"):
    for name, g in [("rmat", rmat_graph(11, 16, seed=6)),
                    ("grid", grid_graph(30, 30)),
                    ("er", erdos_renyi_graph(4000, 30000, seed=5)),
                    ("path", path_graph(2001))]:
        sched = build_window_schedule(g, window=1024, tile_size=256,
                                      reorder=policy)
        rd, st = distributed_skipper(g, block_size=512, schedule=sched)
        out = assert_matching(g, rd.match_mask, f"sharded{{D}}/{{policy}}/{{name}}")
        assert st.ok, (policy, name)
        ms = int(sgmm(g).num_matches)
        assert out["num_matches"] >= ms / 2, (policy, name)
        # the window tier is D-invariant: windows are disjoint vertex
        # ranges, so the dense-tier decisions equal the single-device
        # pipeline's no matter which device ran each window.
        rk = skipper_match(g, schedule=sched, backend="xla")
        slots = sched.num_rows * sched.tiles_per_window * sched.tile_size
        wsel = sched.stream_src < slots
        assert bool((np.asarray(rd.match_mask)[wsel]
                     == np.asarray(rk.match_mask)[wsel]).all()), (policy, name)
        # determinism: same schedule -> same output
        rd2, _ = distributed_skipper(g, block_size=512, schedule=sched)
        assert bool((np.asarray(rd.match_mask)
                     == np.asarray(rd2.match_mask)).all()), (policy, name)
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
@pytest.mark.subprocess
@pytest.mark.parametrize("num_devices", [2, 4])
def test_sharded_equivalence_matrix_multi_device(num_devices):
    """Every reorder policy x D in {2, 4}: valid maximal matchings, >= half
    of sgmm, window-tier decisions bit-equal to the single-device pipeline,
    deterministic. (D=1 runs in-process in
    test_sharded_single_device_bit_identical_to_skipper_match.)"""
    _run_subprocess(
        _EQUIV_SCRIPT_TEMPLATE.format(D=num_devices), num_devices
    )


_SUBPROCESS_SCRIPT = r"""
import jax
assert len(jax.devices()) == 8, jax.devices()
import numpy as np
from repro.graphs import rmat_graph, grid_graph, erdos_renyi_graph, star_graph, path_graph
from repro.core.distributed import distributed_skipper
from repro.core import assert_matching, sgmm

for name, g in [("grid", grid_graph(30, 30)),
                ("er", erdos_renyi_graph(4000, 30000, seed=5)),
                ("star", star_graph(400)),
                ("path", path_graph(2001)),
                ("rmat", rmat_graph(11, 16, seed=6))]:
    r, st = distributed_skipper(g, block_size=128)
    out = assert_matching(g, r.match_mask, f"dist8/{name}")
    assert st.ok, name
    ms = int(sgmm(g).num_matches)
    assert out["num_matches"] >= ms / 2, (name, out["num_matches"], ms)
    # determinism: same schedule -> same output
    r2, _ = distributed_skipper(g, block_size=128)
    assert bool((r.match_mask == r2.match_mask).all()), name
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_distributed_eight_devices():
    _run_subprocess(_SUBPROCESS_SCRIPT, num_devices=8)
