"""Distributed Skipper: protocol correctness on 1 device in-process and on 8
forced host devices in a subprocess (so the main pytest process keeps its
single-device jax)."""
import os
import subprocess
import sys

import pytest

from repro.core import assert_matching, sgmm
from repro.core.distributed import distributed_skipper
from repro.graphs import erdos_renyi_graph, grid_graph, star_graph


@pytest.mark.parametrize("gname,g", [
    ("grid", grid_graph(20, 20)),
    ("er", erdos_renyi_graph(2000, 8000, seed=9)),
    ("star", star_graph(150)),
])
def test_distributed_single_device(gname, g):
    result, stats = distributed_skipper(g, block_size=128)
    assert_matching(g, result.match_mask, f"dist1/{gname}")
    assert int(stats.retry_overflow) == 0
    assert int(stats.undrained) == 0
    # one device -> no cross-device conflicts possible
    assert int(stats.lost_proposals) == 0


_SUBPROCESS_SCRIPT = r"""
import jax
assert len(jax.devices()) == 8, jax.devices()
import numpy as np
from repro.graphs import rmat_graph, grid_graph, erdos_renyi_graph, star_graph, path_graph
from repro.core.distributed import distributed_skipper
from repro.core import assert_matching, sgmm

for name, g in [("grid", grid_graph(30, 30)),
                ("er", erdos_renyi_graph(4000, 30000, seed=5)),
                ("star", star_graph(400)),
                ("path", path_graph(2001)),
                ("rmat", rmat_graph(11, 16, seed=6))]:
    r, st = distributed_skipper(g, block_size=128)
    out = assert_matching(g, r.match_mask, f"dist8/{name}")
    assert int(st.retry_overflow) == 0, name
    assert int(st.undrained) == 0, name
    ms = int(sgmm(g).num_matches)
    assert out["num_matches"] >= ms / 2, (name, out["num_matches"], ms)
    # determinism: same schedule -> same output
    r2, _ = distributed_skipper(g, block_size=128)
    assert bool((r.match_mask == r2.match_mask).all()), name
print("SUBPROCESS_OK")
"""


def test_distributed_eight_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SUBPROCESS_OK" in proc.stdout
