"""Checkpointing: roundtrip, digest integrity, latest-resume, gc."""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.checkpoint import Checkpointer


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(5, t, metadata={"loss": 1.5})
    restored, _, meta = ck.restore(None, t)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_resume_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    for s in (10, 20, 30):
        ck.save(s, t)
    assert ck.latest_step() == 30


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]


def test_digest_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(1, t)
    d = os.path.join(str(tmp_path), "step_00000001")
    data = dict(np.load(os.path.join(d, "params.npz")))
    data["a"] = data["a"] + 1.0
    np.savez(os.path.join(d, "params.npz"), **data)
    with pytest.raises(IOError):
        ck.restore(1, t)


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)}}
    with pytest.raises((ValueError, IOError)):
        ck.restore(1, bad)


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(7, tree())
    ck.wait()
    assert ck.latest_step() == 7
