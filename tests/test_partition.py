"""Locality-sharded partitioning (graphs/partition.partition_schedule) and
the heap-based greedy reorder.

The partitioner invariants the distributed matcher relies on:

* every schedule row is dealt to exactly one device (the window tier's
  disjointness, which is what makes it communication-free);
* the boundary stream is dealt round-robin, covering every global-tier edge
  exactly once and — at D=1 — in stream order (the bit-identity anchor);
* block_size must align with tile_size (slab tiles == epilogue tiles).

The greedy reorder's heap selection is pinned bit-identical to the retired
O(V^2/window) argmax reference on every generator family, and must complete
a 10^6-vertex graph (the argmax path was quadratic: ~10^9 scalar compares
for this input).
"""
import time

import numpy as np
import pytest

from repro.graphs import (
    DeviceSchedule,
    build_window_schedule,
    dispersed_blocks,
    erdos_renyi_graph,
    grid_graph,
    partition_schedule,
    path_graph,
    rmat_graph,
    star_graph,
)
from repro.graphs.reorder import (
    _reorder_greedy,
    _reorder_greedy_argmax,
    intra_window_fraction,
)

GRAPHS = {
    "rmat": rmat_graph(11, 16, seed=3),
    "grid": grid_graph(30, 30),
    "er": erdos_renyi_graph(2000, 8000, seed=9),
    "star": star_graph(150),
    "path": path_graph(501),
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("num_devices", [1, 2, 4])
def test_partition_deals_every_row_once(gname, num_devices):
    sched = build_window_schedule(GRAPHS[gname], window=256, tile_size=64,
                                  reorder="degree")
    ds = partition_schedule(sched, num_devices, block_size=128)
    slots = sched.tiles_per_window * sched.tile_size
    dealt = ds.row_slot[ds.row_slot >= 0]
    assert sorted(dealt.tolist()) == list(range(sched.num_rows))
    # dealt row content matches the schedule row it claims to be
    for d in range(num_devices):
        for j in range(ds.rows_per_device):
            r = int(ds.row_slot[d, j])
            if r < 0:
                assert (ds.u_rows[d, j] == -1).all()
                continue
            assert (ds.u_rows[d, j] == sched.u_tiles[r]).all()
            assert (ds.v_rows[d, j] == sched.v_tiles[r]).all()
    assert ds.u_rows.shape == (num_devices, ds.rows_per_device, slots)


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("num_devices", [1, 2, 4])
def test_partition_boundary_round_robin_covers_stream(gname, num_devices):
    sched = build_window_schedule(GRAPHS[gname], window=256, tile_size=64,
                                  reorder="degree")
    ds = partition_schedule(sched, num_devices, block_size=64)
    nb_pad = sched.num_boundary_padded
    # positions: every real boundary slot appears exactly once
    pos = ds.boundary_ib[ds.boundary_ib >= 0]
    real = np.nonzero(sched.boundary_index >= 0)[0]
    assert sorted(pos.tolist()) == real.tolist()
    # round-robin deal: round r of device d is stream block r*D + d
    d_, r_, b_ = np.nonzero(ds.boundary_ib >= 0)
    stream = (r_ * num_devices + d_) * ds.block_size + b_
    assert (ds.boundary_ib[d_, r_, b_] == stream).all()
    # the dealt endpoints are the schedule's boundary endpoints
    assert (ds.boundary_ub[d_, r_, b_] == sched.boundary_u[stream]).all()
    assert (ds.boundary_vb[d_, r_, b_] == sched.boundary_v[stream]).all()
    if num_devices == 1 and nb_pad:
        flat = ds.boundary_ib.reshape(-1)[:nb_pad]
        want = np.where(sched.boundary_index >= 0,
                        np.arange(nb_pad, dtype=np.int32), -1)
        assert (flat == want).all()  # D=1: the stream, in order


def test_partition_rejects_misaligned_block_size():
    sched = build_window_schedule(GRAPHS["grid"], window=256, tile_size=64)
    with pytest.raises(ValueError, match="multiple of tile_size"):
        partition_schedule(sched, 2, block_size=96)


def test_partition_balances_windowed_edges():
    """LPT deal: no device holds more than ~the densest single row above the
    mean (the classic LPT bound), measured on a skewed reordered RMAT."""
    sched = build_window_schedule(rmat_graph(12, 16, seed=1), window=512,
                                  tile_size=128, reorder="degree")
    if sched.num_rows < 4:
        pytest.skip("schedule coalesced to too few rows to balance")
    ds = partition_schedule(sched, 2, block_size=128)
    per_dev = (ds.u_rows >= 0).sum(axis=(1, 2))
    counts = (sched.edge_index >= 0).sum(axis=1)
    assert per_dev.max() <= per_dev.mean() + counts.max()
    assert ds.window_balance >= 1.0


def test_dispersed_blocks_reorder_mode_returns_device_schedule():
    g = GRAPHS["rmat"]
    ds = dispersed_blocks(g, 2, 256, reorder="degree", window=512)
    assert isinstance(ds, DeviceSchedule)
    assert ds.schedule.reorder == "degree"
    assert ds.num_devices == 2
    # plain mode unchanged: a (u, v) block pair
    ub, vb = dispersed_blocks(g.canonical(), 2, 256)
    assert ub.shape[0] == 2 and ub.shape == vb.shape


# --- heap-based greedy reorder -------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("window", [64, 256])
def test_greedy_heap_matches_argmax_reference(gname, window):
    """The production heap selection is bit-identical to the retired full
    argmax — same ordering, not just same quality."""
    g = GRAPHS[gname]
    a = _reorder_greedy_argmax(g, window)
    b = _reorder_greedy(g, window)
    assert np.array_equal(a.inv, b.inv)
    assert np.array_equal(a.perm, b.perm)


def test_greedy_completes_million_vertex_graph():
    """Acceptance: the greedy policy must feed the partitioner at paper
    scale. 2^20 vertices / ~2.1M edges finishes in seconds on the heap path
    (the argmax path was O(V^2/window): ~5 * 10^11 compares here)."""
    g = grid_graph(1024, 1024)  # 2^20 vertices
    t0 = time.time()
    r = _reorder_greedy(g, 2048)
    elapsed = time.time() - t0
    assert r.num_vertices == 1024 * 1024
    # a real permutation
    assert np.array_equal(np.sort(r.inv), np.arange(g.num_vertices))
    assert intra_window_fraction(g, 2048, r) > 0.5
    assert elapsed < 120, f"heap greedy too slow: {elapsed:.0f}s"
