"""Optional-hypothesis shim shared by test modules whose deterministic pins
should still run in containers without the [dev] deps.

When hypothesis is installed, re-exports the real ``given`` / ``settings`` /
``st``. When it is not, ``given``/``settings`` become decorators that mark
the test skipped and ``st`` becomes a stub whose strategy constructors are
inert (they are only evaluated at decoration time).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class st:  # noqa: N801 - strategy stubs, evaluated at decoration only
        _inert = staticmethod(lambda *a, **k: None)
        integers = floats = booleans = sampled_from = lists = text = _inert
        tuples = _inert
