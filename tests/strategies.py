"""Shared test strategies: graph/stream generators, the optional-hypothesis
shim, and the forced-device subprocess runner.

This folds the old ``_hyp.py`` shim in — import ``given`` / ``settings`` /
``st`` from here. When hypothesis is installed they are the real thing;
when it is not (minimal containers), ``given``/``settings`` decorate the
test as skipped and ``st`` is an inert stub (its strategy constructors are
only evaluated at decoration time). Deterministic pins in the same module
keep running either way.

The generators are plain numpy builders shared by the per-file suites
(matching core, boundary pair, statespec, faults, APRAM conformance) so
each file stops growing its own slightly-different ``_graph`` helper:

* :func:`random_edge_list` — uniform endpoints, with optional knobs for
  the stream hazards the protocol must survive (self-loops, duplicate
  slots, invalid ``-1`` padding, canonicalization).
* :func:`adversarial_edge_list` — the contention mix the fuzzer uses
  (hub fan-in + chain runs + duplicates + self-loops + padding).
* :func:`random_candidate_stream` — b-matching candidate streams with
  invalid slots, for the bipartite/MoE suites.
* :func:`run_subprocess` — run a script under
  ``--xla_force_host_platform_device_count=N`` (moved here from
  test_distributed so the faults/statespec/apram suites stop importing a
  test module for it).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class st:  # noqa: N801 - strategy stubs, evaluated at decoration only
        _inert = staticmethod(lambda *a, **k: None)
        integers = floats = booleans = sampled_from = lists = text = _inert
        tuples = _inert


#: common strategy bundles (inert without hypothesis — decoration-time only)
seeds = st.integers(0, 2**31 - 1)


# ---------------------------------------------------------------------------
# graph / stream builders (plain numpy; no hypothesis dependency)
# ---------------------------------------------------------------------------
def random_edge_list(rng, n, m, *, canonical=False, self_loops=0.0,
                     duplicates=0.0, invalid=0.0):
    """Uniform random ``EdgeList`` with optional stream hazards.

    ``rng`` is a ``numpy.random.Generator`` or an int seed. ``self_loops``
    / ``duplicates`` / ``invalid`` are per-slot probabilities: loops force
    ``v = u``, duplicates copy another stream slot, invalid slots become
    ``(-1, -1)`` padding. ``canonical=True`` returns ``u <= v`` per edge
    (what the window-schedule builders expect)."""
    import jax.numpy as jnp

    from repro.graphs.types import EdgeList

    if not isinstance(rng, np.random.Generator):
        rng = np.random.default_rng(rng)
    u = rng.integers(0, n, m).astype(np.int64)
    v = rng.integers(0, n, m).astype(np.int64)
    if duplicates:
        dup = rng.random(m) < duplicates
        src = rng.integers(0, m, m)
        u = np.where(dup, u[src], u)
        v = np.where(dup, v[src], v)
    if self_loops:
        v = np.where(rng.random(m) < self_loops, u, v)
    if invalid:
        pad = rng.random(m) < invalid
        u = np.where(pad, -1, u)
        v = np.where(pad, -1, v)
    if canonical:
        u, v = np.minimum(u, v), np.maximum(u, v)
    return EdgeList(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                    int(n))


def adversarial_edge_list(seed, n=64, m=192):
    """The fuzzer's contention mix as an ``EdgeList``: a few hot hubs,
    path-like chain runs, duplicate slots, self-loops and invalid padding
    — the shapes reservation-order bugs are sensitive to."""
    import jax.numpy as jnp

    from repro.graphs.types import EdgeList

    rng = np.random.default_rng(seed)
    hubs = rng.integers(0, max(2, n // 10), m)
    chain = np.arange(m) % (n - 1)
    ru = rng.integers(0, n, m)
    rv = rng.integers(0, n, m)
    pick = rng.integers(0, 4, m)
    u = np.select([pick == 0, pick == 1], [hubs, chain], ru)
    v = np.select([pick == 0, pick == 1], [rv, chain + 1], rv)
    dup = rng.random(m) < 0.10
    src = rng.integers(0, m, m)
    u = np.where(dup, u[src], u)
    v = np.where(dup, v[src], v)
    v = np.where(rng.random(m) < 0.05, u, v)
    pad = rng.random(m) < 0.08
    u = np.where(pad, -1, u)
    v = np.where(pad, -1, v)
    return EdgeList(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                    int(n))


def random_candidate_stream(rng, num_tokens, num_experts, m, *,
                            invalid=0.05):
    """B-matching candidate stream ``(token_ids, expert_ids)`` as int32
    numpy arrays, with ``invalid`` fraction of ``token_id = -1`` slots."""
    if not isinstance(rng, np.random.Generator):
        rng = np.random.default_rng(rng)
    tok = rng.integers(0, num_tokens, m).astype(np.int32)
    exp = rng.integers(0, num_experts, m).astype(np.int32)
    if invalid:
        tok[rng.random(m) < invalid] = -1
    return tok, exp


# ---------------------------------------------------------------------------
# forced-device subprocess runner (from test_distributed)
# ---------------------------------------------------------------------------
def run_subprocess(script: str, num_devices: int, timeout: int = 900):
    """Run ``script`` in a fresh interpreter with
    ``--xla_force_host_platform_device_count=num_devices`` (the main pytest
    process keeps its single-device jax). The script must print
    ``SUBPROCESS_OK`` on success."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={num_devices}"
    )
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SUBPROCESS_OK" in proc.stdout, proc.stdout[-2000:]
