"""AdamW + schedule unit tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig
from repro.optim import adamw


def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = adamw.init_state(params, tcfg)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, lr, gn = adamw.apply_updates(params, g, state, tcfg)
    assert float(loss_fn(params)) < 1e-2


def test_grad_clip():
    g = {"w": jnp.asarray([30.0, 40.0])}       # norm 50
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 50.0) < 1e-4
    n2 = float(jnp.linalg.norm(clipped["w"]))
    assert abs(n2 - 1.0) < 1e-4


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.cosine_lr(jnp.asarray(s), tcfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert lrs[10] >= lrs[50] >= lrs[99]           # cosine decays
    assert lrs[99] >= 0.1 * 1e-3 * 0.99            # floor at 10%


def test_bf16_moments():
    # lr large enough that a single step is visible at bf16 resolution
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw.init_state(params, tcfg, moment_dtype=jnp.bfloat16)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.5, jnp.bfloat16)}
    p2, s2, _, _ = adamw.apply_updates(params, g, state, tcfg)
    assert s2.mu["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(p2["w"] - params["w"]).sum()) > 0


def test_weight_decay_only_on_matrices():
    tcfg = TrainConfig(learning_rate=1e-2, weight_decay=1.0, warmup_steps=1)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = adamw.init_state(params, tcfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _, _ = adamw.apply_updates(params, zero_g, state, tcfg)
    assert float(jnp.abs(p2["mat"] - 1.0).sum()) > 0     # decayed
    assert float(jnp.abs(p2["vec"] - 1.0).sum()) == 0    # not decayed
