"""Data pipeline: determinism, sharding disjointness, matching-based packing."""
import numpy as np
import pytest

from strategies import given, settings, st  # noqa: E402

from repro.data import (
    DataConfig, batch_for_step, documents_for_step, pack_documents,
    packing_efficiency,
)


def test_batches_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=128, batch_per_host=4)
    a1, m1 = batch_for_step(7, cfg)
    a2, m2 = batch_for_step(7, cfg)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(m1, m2)


def test_hosts_get_disjoint_streams():
    cfg0 = DataConfig(vocab_size=1000, seq_len=128, batch_per_host=4, num_hosts=2, host_id=0)
    cfg1 = DataConfig(vocab_size=1000, seq_len=128, batch_per_host=4, num_hosts=2, host_id=1)
    a0, _ = batch_for_step(3, cfg0)
    a1, _ = batch_for_step(3, cfg1)
    assert not np.array_equal(a0, a1)


def test_steps_differ():
    cfg = DataConfig(vocab_size=1000, seq_len=128, batch_per_host=4)
    a0, _ = batch_for_step(0, cfg)
    a1, _ = batch_for_step(1, cfg)
    assert not np.array_equal(a0, a1)


def test_pack_documents_valid():
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 100, size=rng.integers(10, 100)).astype(np.int32)
            for _ in range(16)]
    rows, mask = pack_documents(docs, 8, 128)
    assert rows.shape == (8, 128)
    assert mask.shape == (8, 128)
    # tokens only where mask
    assert (rows[~mask] == 0).all()
    assert (rows[mask] > 0).all()


def test_packing_beats_one_doc_per_row():
    """Matching-based packing fills rows better than one-doc-per-row."""
    rng = np.random.default_rng(1)
    docs = [rng.integers(1, 100, size=int(l)).astype(np.int32)
            for l in rng.integers(20, 120, size=32)]
    rows_packed, mask_packed = pack_documents(docs, 16, 128)
    rows_plain = np.zeros((16, 128), np.int32)
    mask_plain = np.zeros((16, 128), bool)
    for i in range(16):
        d = docs[i][:128]
        rows_plain[i, : len(d)] = d
        mask_plain[i, : len(d)] = True
    assert packing_efficiency(mask_packed) > packing_efficiency(mask_plain)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_docs=st.integers(1, 40),
    seq_len=st.sampled_from([64, 128, 256]),
)
def test_property_packing_never_splits_docs_across_rows(seed, n_docs, seq_len):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(1, 100, size=int(l)).astype(np.int32)
            for l in rng.integers(8, seq_len, size=n_docs)]
    rows, mask = pack_documents(docs, n_docs, seq_len)
    # each row's mask is a prefix-contiguous region (docs are packed head-on)
    for r in range(rows.shape[0]):
        m = mask[r]
        if m.any():
            last = np.nonzero(m)[0].max()
            assert m[: last + 1].all()
