"""Structural HLO text analysis with while-loop trip-count correction.

XLA's HloCostAnalysis (compiled.cost_analysis()) visits each while-loop BODY
exactly once — for lax.scan-based models (every LM here scans its layers)
that undercounts flops/bytes/collectives by the layer count (verified: a
scan of 8 matmuls reports the flops of 1).

This module parses the post-SPMD, post-optimization HLO text instead:

  * splits the module into computations and builds a per-computation symbol
    table (op name -> result shape) so operand shapes resolve exactly,
  * builds the call graph (while body/condition, conditional branches,
    fusion bodies) and reads each while's trip count from its
    ``backend_config known_trip_count`` (fallback: the condition's
    compare-against-constant),
  * multiplies per-op costs by the product of enclosing trip counts.

Cost model per (trip-count-scaled) op:
  * flops: ``dot`` -> 2 * |result| * prod(contracting dims); dots inside
    fusion bodies are counted too (scaled by the fusion call site).
  * HBM traffic: operand + result bytes of top-level ops (fusion call sites,
    dots, collectives, scatters/gathers, copies, DUS) — fusion boundaries
    are the HBM round trips; fusion-internal elementwise ops stay in
    registers and are excluded.
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async ``-start``
    counted once, ``-done`` skipped).

Shapes in the partitioned module are per-device => all outputs per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "custom-call", "scatter", "gather",
    "reduce", "sort", "copy", "dynamic-update-slice", "dynamic-slice",
    "transpose", "reshape", "broadcast", "concatenate", "slice", "pad",
    "select", "compare", "add", "multiply", "exponential", "rng",
    "cholesky", "triangular-solve", "select-and-scatter", "reduce-window",
    "reverse",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    return sum(
        _DTYPE_BYTES.get(dt, 0) * _shape_elems(dims)
        for dt, dims in _SHAPE_RE.findall(text)
    )


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            if s.endswith("{") and (") -> " in s or s.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def _entry_name(text: str, comps: Dict[str, List[str]]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    for name in comps:
        if "main" in name:
            return name
    return next(iter(comps))


def _parse_def(line: str) -> Optional[Tuple[str, str, str]]:
    """-> (name, result_type_str, rest_after_type) or None."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # result type: up to the op token. Type may be a tuple "(...)" or scalar.
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return name, rhs[: i + 1], rhs[i + 1 :].strip()
        return None
    parts = rhs.split(None, 1)
    if len(parts) != 2:
        return None
    return name, parts[0], parts[1]


def _op_and_args(rest: str) -> Tuple[Optional[str], str]:
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None, ""
    op = m.group(1)
    start = rest.find("(")
    depth = 0
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            return op, rest[start + 1 : i]
    return op, rest[start + 1 :]


def _trip_count_from_line(line: str, cond_lines: List[str]) -> int:
    m = re.search(r"known_trip_count[^}]*?\\?\"n\\?\":\\?\"(\d+)\\?\"", line)
    if m:
        return max(int(m.group(1)), 1)
    consts = {}
    for cl in cond_lines:
        cm = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)", cl)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))
    if len(consts) == 1:
        return max(next(iter(consts.values())), 1)
    return 1


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    while_trip_counts: List[int]


def analyze_hlo(text: str) -> HloCosts:
    comps = _split_computations(text)
    entry = _entry_name(text, comps)

    # per-computation symbol tables + parsed op lines
    parsed: Dict[str, List[Tuple[str, str, str, str]]] = {}
    symtab: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        table: Dict[str, str] = {}
        ops: List[Tuple[str, str, str, str]] = []
        for line in lines:
            d = _parse_def(line)
            if d is None:
                continue
            name, rtype, rest = d
            table[name] = rtype
            op, args = _op_and_args(rest)
            if op:
                ops.append((name, rtype, op, line))
        parsed[cname] = ops
        symtab[cname] = table

    # call graph: while loops, conditionals, fusions
    while_edges: List[Tuple[str, str, str, int]] = []
    flop_edges: List[Tuple[str, str]] = []   # callee counted for flops only
    for cname, ops in parsed.items():
        for name, rtype, op, line in ops:
            if op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                if cm and bm:
                    tc = _trip_count_from_line(line, comps.get(cm.group(1), []))
                    while_edges.append((cname, bm.group(1), cm.group(1), tc))
            elif op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation)=%?([\w\.\-]+)", line):
                    flop_edges.append((cname, m.group(1)))
                bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                if bm:
                    for t in bm.group(1).split(","):
                        flop_edges.append((cname, t.strip().lstrip("%")))
            elif op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", line)
                if m:
                    flop_edges.append((cname, m.group(1)))

    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    for _ in range(64):
        changed = False
        for caller, body, cond, tc in while_edges:
            base = mult.get(caller, 0.0)
            for target, factor in ((body, tc), (cond, tc + 1)):
                val = base * factor
                if target in mult and val > mult[target]:
                    mult[target] = val
                    changed = True
        for caller, callee in flop_edges:
            val = mult.get(caller, 0.0)
            if callee in mult and val > mult[callee]:
                mult[callee] = val
                changed = True
        if not changed:
            break

    def operand_bytes(cname: str, op: str, line: str) -> int:
        _, _, rest = _parse_def(line)
        _, args = _op_and_args(rest)
        total = 0
        for m in re.finditer(r"%([\w\.\-]+)", args):
            t = symtab[cname].get(m.group(1))
            if t:
                total += _shapes_bytes(t)
        return total

    def dot_flops_of(cname: str, line: str) -> float:
        d = _parse_def(line)
        if d is None:
            return 0.0
        _, rtype, rest = d
        result = sum(
            _shape_elems(dims) for _, dims in _SHAPE_RE.findall(rtype)
        )
        _, args = _op_and_args(rest)
        names = re.findall(r"%([\w\.\-]+)", args)
        lhs_shape = None
        if names:
            t = symtab[cname].get(names[0])
            if t:
                sh = _SHAPE_RE.findall(t)
                if sh:
                    lhs_shape = [int(x) for x in sh[0][1].split(",") if x]
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        contract = 1
        if m and lhs_shape:
            for ax in m.group(1).split(","):
                if ax != "" and int(ax) < len(lhs_shape):
                    contract *= lhs_shape[int(ax)]
        return 2.0 * result * contract

    dot_flops = 0.0
    traffic = 0.0
    coll: Dict[str, float] = {}
    # computations reachable only as fusion bodies: flops yes, traffic no
    fusion_bodies = {callee for _, callee in flop_edges}
    toplevel = {entry} | {b for _, b, _, _ in while_edges} | {c for _, _, c, _ in while_edges}

    for cname, ops in parsed.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        is_toplevel = cname in toplevel
        for name, rtype, op, line in ops:
            if op.endswith("-done"):
                continue
            base = op.replace("-start", "")
            if op == "dot":
                dot_flops += k * dot_flops_of(cname, line)
            if not is_toplevel:
                continue  # fusion/branch body: no direct HBM traffic
            if base in _COLLECTIVES:
                b = operand_bytes(cname, op, line)
                coll[base] = coll.get(base, 0.0) + k * b
                traffic += k * (b + _shapes_bytes(rtype))
            elif op in _TRAFFIC_OPS:
                traffic += k * (
                    operand_bytes(cname, op, line) + _shapes_bytes(rtype)
                )

    return HloCosts(
        dot_flops=dot_flops,
        traffic_bytes=traffic,
        collective_bytes=float(sum(coll.values())),
        collective_breakdown=coll,
        while_trip_counts=[tc for _, _, _, tc in while_edges],
    )
