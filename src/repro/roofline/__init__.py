from repro.roofline.analysis import (
    RooflineTerms,
    analyze,
    collective_bytes,
    model_flops,
    PEAK_FLOPS,
    HBM_BW,
    LINK_BW,
    HBM_PER_CHIP,
    state_traffic_bytes,
    vmem_step_bytes,
)

__all__ = [
    "RooflineTerms", "analyze", "collective_bytes", "model_flops",
    "PEAK_FLOPS", "HBM_BW", "LINK_BW", "HBM_PER_CHIP",
    "state_traffic_bytes", "vmem_step_bytes",
]
