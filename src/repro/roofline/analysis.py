"""Roofline term derivation from compiled dry-run artifacts.

For each (arch x shape x mesh) the dry-run records:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

cost_analysis() on an SPMD-partitioned executable reports *per-device*
numbers (verified empirically in tests/test_roofline.py), so no chip division
is applied to them; collective bytes are parsed from the partitioned HLO —
also per-device — by summing operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (i.e. the spec's
"collective_bytes / (chips x link_bw)" with both sides already per-chip).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we use 1 link; multi-link meshes only improve the term).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes summed over the (per-device) module.

    HLO line shape: ``%x = TYPE op-name(operands...)`` — the first
    dtype[shape] token is the result; operand shapes are parsed from inside
    the call parens when present, else we fall back to the result size (for
    all-reduce operand == result anyway).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+)(?:-start|-done)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        # operands: shapes appearing after the opening paren of the op call
        call_idx = stripped.find(base + "(")
        if call_idx == -1:
            call_idx = stripped.find("(")
        operand_str = stripped[call_idx:]
        shapes = _SHAPE_RE.findall(operand_str)
        if not shapes:
            shapes = _SHAPE_RE.findall(stripped)[:1]
        total = sum(_shape_bytes(d, s) for d, s in shapes)
        out[base] += total
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    coll_breakdown: Dict[str, int]
    bytes_per_device: int        # resident (args + temps)
    compute_s: float
    memory_s: float
    collective_s: float
    cpu_convert_artifact: int = 0   # bf16->f32 dot-emulation buffers (absent on TPU)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time model: dominant term (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "bytes_per_device": self.bytes_per_device,
            "cpu_convert_artifact_bytes": self.cpu_convert_artifact,
            "bytes_per_device_tpu_corrected": self.bytes_per_device - self.cpu_convert_artifact,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def analyze(compiled, hlo_text: Optional[str] = None) -> RooflineTerms:
    """Terms from the trip-count-corrected structural HLO parse
    (roofline/hlo_parse.py). Raw cost_analysis() is NOT usable directly: XLA
    visits while (lax.scan) bodies once, undercounting layer-scanned models
    by ~num_layers x (verified in tests/test_roofline.py)."""
    from repro.roofline.hlo_parse import analyze_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = analyze_hlo(text)
    flops = costs.dot_flops
    hbm = costs.traffic_bytes
    coll_total = costs.collective_bytes
    ma = compiled.memory_analysis()
    resident = int(
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )
    # XLA:CPU emulates bf16 dots by promoting operands to f32; the hoisted
    # convert buffers (absent on TPU, where the MXU consumes bf16 natively)
    # inflate temp memory. Quantify them so the fits-HBM check can report a
    # TPU-corrected resident size alongside the raw one.
    artifact = _cpu_convert_artifact_bytes(text)
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll_total,
        coll_breakdown={k: int(v) for k, v in costs.collective_breakdown.items() if v},
        bytes_per_device=resident,
        cpu_convert_artifact=artifact,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=coll_total / LINK_BW,
    )


_CONVERT_RE = re.compile(
    r"=\s*f32\[([\d,]+)\][^=]*fusion\([^)]*\),\s*kind=kLoop,"
    r"\s*calls=%wrapped_convert"
)


def _cpu_convert_artifact_bytes(text: str) -> int:
    total = 0
    for m in _CONVERT_RE.finditer(text):
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        total += 4 * n
    return total


def state_traffic_bytes(counters, spec=None) -> Dict[str, float]:
    """State-array traffic of a matcher run in BYTES under a state spec.

    ``core/types.Counters`` counts state *accesses* (the paper's PAPI
    convention — loads + stores of ``state[]``); the roofline wants bytes,
    and the byte-per-access factor is exactly what ``core/statespec``
    decides: the hot loop touches state at the spec's VMEM width, so the
    uint8 default moves 4x fewer state bytes than the legacy int32 graph
    for the same access counts. Edge-topology reads stay int32 (2 endpoint
    ids, 8 B per edge read) at every spec.

    Returns ``{"state_bytes", "edge_bytes", "total_bytes", "memory_s"}``
    where ``memory_s`` is the HBM term these bytes contribute at the
    modeled bandwidth.
    """
    from repro.core.statespec import resolve as resolve_spec

    spec = resolve_spec(spec)
    accesses = int(counters.state_loads) + int(counters.state_stores)
    state_b = float(accesses * spec.vmem_bytes)
    edge_b = float(int(counters.edge_reads) * 8)  # 2 x i32 endpoints
    total = state_b + edge_b
    return {
        "state_bytes": state_b,
        "edge_bytes": edge_b,
        "total_bytes": total,
        "memory_s": total / HBM_BW,
    }


def model_flops(cfg, shape, n_params_active: int, n_params_total: int) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*tokens for inference."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def vmem_step_bytes(target: str = "boundary_kernel") -> Dict[str, Dict]:
    """Per-grid-step VMEM byte budget of a traced kernel target, keyed by
    kernel name — the static companion to :func:`state_traffic_bytes`.

    Delegates to the conformance analyzer (``repro.analysis``): the target
    is traced to a jaxpr on CPU and each pallas kernel's resident bytes
    are decomposed into double-buffered blocks, scratch, and a liveness
    upper bound on intermediates — the same numbers the ``vmem-budget``
    rule gates in CI, surfaced here so roofline studies can quote them.
    Targets: see ``repro.analysis.targets.TARGETS`` (e.g.
    ``boundary_kernel``, ``pipeline_kernel``, ``flash_attention``).
    """
    from repro.analysis.rules.vmem_budget import kernel_step_bytes
    from repro.analysis.targets import get_targets
    from repro.analysis.trace import collect_pallas_calls

    (tgt,) = get_targets([target])
    arts = collect_pallas_calls(tgt.trace(1), tgt.name)
    return {a.name: kernel_step_bytes(a) for a in arts}
