"""Fault-tolerant checkpointing: versioned step directories, atomic rename,
content digest, async save thread, automatic latest-step resume, and
logical (mesh-independent) storage so a restart may use a different device
count (elastic restart).

Format: one .npz per pytree (params / optimizer / metadata msgpack-free
JSON), flattened by path string. Arrays are gathered to host (at laptop
scale) — a real deployment would swap `_to_host` for per-shard OCDBT writes;
the directory/commit protocol (tmp dir + digest + atomic rename) is the part
that carries over unchanged.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # numpy .npz cannot round-trip ml_dtypes; store the raw bits
            arr = arr.view(np.uint16)
            key = key + "::bf16"
        flat[key] = arr
    return flat


def _digest(flat: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes()[:65536])
    return h.hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, params: Any, opt_state: Any = None,
             metadata: Optional[Dict] = None, block: bool = False) -> None:
        flat_p = _flatten(params)
        flat_o = _flatten(opt_state) if opt_state is not None else {}
        meta = dict(metadata or {})
        meta["step"] = int(step)
        meta["time"] = time.time()

        def _write():
            # unique tmp dir: a blocking save may overlap a still-running
            # async save of the same step
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{time.monotonic_ns()}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "params.npz"), **flat_p)
            if flat_o:
                np.savez(os.path.join(tmp, "opt_state.npz"), **flat_o)
            meta["params_digest"] = _digest(flat_p)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # commit point: atomic
            self._gc()

        self.wait()  # serialize with any in-flight async save
        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: Optional[int], params_like: Any, opt_like: Any = None
    ) -> Tuple[Any, Any, Dict]:
        """Restore into the structure of `params_like` (shape/dtype checked;
        sharding re-applied by the caller's jit/device_put — elastic)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        flat_p = dict(np.load(os.path.join(d, "params.npz")))
        if meta.get("params_digest") and _digest(flat_p) != meta["params_digest"]:
            raise IOError(f"checkpoint step {step}: params digest mismatch")
        params = _unflatten_like(params_like, flat_p)
        opt_state = None
        if opt_like is not None and os.path.exists(os.path.join(d, "opt_state.npz")):
            flat_o = dict(np.load(os.path.join(d, "opt_state.npz")))
            opt_state = _unflatten_like(opt_like, flat_o)
        return params, opt_state, meta


def _unflatten_like(like: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key + "::bf16" in flat:
            import ml_dtypes
            arr = flat[key + "::bf16"].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        out.append(np.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
