"""Shared types for the matching core.

Vertex states follow the paper (Alg. 1): ACC(0) accessible, RSVD(1) reserved,
MCHD(2) matched. The at-rest state array is uint8 — the paper's "one byte per
vertex" memory claim (§I, §IV) preserved verbatim. Per-tier widths (VMEM,
wire, counters) live in ``core/statespec.py``; ``STATE_DTYPE`` here is the
default spec's at-rest dtype, kept as the legacy alias most callers use.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.statespec import DEFAULT as DEFAULT_STATE_SPEC

STATE_DTYPE = DEFAULT_STATE_SPEC.at_rest_dtype

ACC = STATE_DTYPE(0)
RSVD = STATE_DTYPE(1)
MCHD = STATE_DTYPE(2)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Counters:
    """Work-efficiency instrumentation (paper §VI-C, Fig. 7).

    All counts are *memory accesses* in the paper's sense: loads + stores of
    the shared state array plus edge-topology reads. Derived analytically from
    what each algorithm actually touches, mirroring the PAPI counters used in
    the paper.
    """

    edge_reads: jax.Array       # topology loads (each edge endpoint pair = 1)
    state_loads: jax.Array      # loads of state[]
    state_stores: jax.Array     # stores to state[]
    rounds: jax.Array           # iterations / passes over (parts of) the graph

    def tree_flatten(self):
        return (self.edge_reads, self.state_loads, self.state_stores, self.rounds), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def total_accesses(self) -> jax.Array:
        return self.edge_reads + self.state_loads + self.state_stores

    @staticmethod
    def zeros() -> "Counters":
        z = jnp.zeros((), jnp.int32)
        return Counters(z, z, z, z)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MatchResult:
    """Output of a matcher.

    match_mask: bool[|E|] aligned with the input edge order — True iff that
        edge was selected. (The paper emits per-thread match buffers; a mask
        over the single-pass edge stream is the equivalent, order-preserving
        representation and what the validators consume.)
    state: uint8[|V|] final vertex states (ACC or MCHD; RSVD never survives).
    counters: work instrumentation.
    """

    match_mask: jax.Array
    state: jax.Array
    counters: Counters

    def tree_flatten(self):
        return (self.match_mask, self.state, self.counters), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_matches(self) -> jax.Array:
        return jnp.sum(self.match_mask)
