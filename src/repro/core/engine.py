"""The shared first-claim engine — Skipper's invariant in ONE place.

Every matcher in this repo (the single-device tiled matcher in
``core/skipper.py``, the shard_map distributed matcher in
``core/distributed.py``, the Pallas TPU kernel in
``kernels/skipper_match/kernel.py`` and its jnp oracle in
``kernels/skipper_match/ref.py``) enforces the same invariant, ported from the
paper's per-edge CAS protocol (Alg. 1):

    every edge is decided (matched / dead) at the moment it is touched, and an
    edge is dead only if one of its endpoints is already MCHD.

The vectorized form of that invariant is the *first-claim round* over a tile
of T edges:

    free_i    = both endpoints ACC and edge undecided
    blocked_i = exists j < i in the tile: free_j and edges i, j share an endpoint
    commit_i  = free_i and not blocked_i      # mutually endpoint-disjoint!

Since PR 4 the same invariant also exists in a *capacitated* form: the
first-K-claim round (``first_k_claim_commit`` + the ``ranks_*`` builders +
``tile_pass_capacitated``), which generalizes the reservation step to
per-side budgets (MoE token budgets / expert capacities — consumed by
``core/bipartite.bmatch_assign``) and degenerates bit-identically to the
unit-capacity rule at cap = 1. See DESIGN.md §9 and the section comment
above ``first_k_claim_commit``.

This module owns the pieces that must never drift between matchers. The
``blocked`` predicate has THREE interchangeable implementations computing
the exact same function (tests pin bit-equality across them):

* ``share_matrix`` + ``blocked_from_matrix`` — the triangular
  endpoint-sharing (JIT-conflict) matrix, O(T^2) VPU compares. Built with
  2-D ``broadcasted_iota`` so the exact same code traces inside a Pallas
  TPU kernel and in plain XLA; the T x T work is native MXU/VPU food, which
  is why the compiled kernel keeps it.
* ``blocked_by_claim_sort`` — per-vertex minimum free claimant via one sort
  of the tile's 2T endpoint slots: edge i is blocked iff some free edge
  j < i claims one of its endpoints, i.e. ``min(claimant(u_i),
  claimant(v_i)) < i``. O(T log T) — the CPU/XLA twin's hot-path version
  (~2.5x end-to-end on the jnp matchers, measured rmat14).
* ``blocked_by_claim_scatter`` — the same claimant function via scatter-min
  into a vertex-indexed [n] claim array; wins when n is small relative to
  the tile (window-local tiles).

``first_claim_commit`` turns gathered endpoint states plus a blocked
predicate into one round's commit/blocked decision. On top sit the standard
drivers:

* ``run_first_claim_rounds`` — the unrolled round loop, parameterized over the
  caller's gather/scatter (the kernel passes MXU one-hot matmuls closing over
  a VMEM ref; jnp callers pass ``.at`` indexing).
* ``greedy_fallback_rounds`` — the exact cleanup of edges that survive the
  unrolled rounds (long conflict chains): iterated first-claim rounds in a
  ``while_loop`` until no free edge remains. The fixpoint is *exactly* the
  sequential index-order greedy matching (see its docstring), so the result
  is identical to a scalar scan of the tile — but each iteration is one
  vectorized round, and under vmap/scan the loop costs only as many
  iterations as the worst surviving chain actually needs (a serial scan
  fallback under vmap degrades to always paying T steps: ``lax.cond``
  becomes ``select`` and runs both branches).
* ``tile_pass`` — the full jnp tile pass (rounds + exact fallback) consumed
  by the single-device and distributed matchers.
* ``tile_pass_pair`` — the two-block variant driving the block-pair
  boundary epilogue (DESIGN.md §10): slice two ``window``-sized state rows,
  run ``tile_pass`` on their concatenation with the schedule's offset-local
  ids, write the halves back. The Pallas pair kernel runs the same rounds +
  fallback over the same concatenation (DMA'd into VMEM scratch), so the
  jnp twin is bit-identical by construction.
* ``window_tier_pass`` — the shared *window tier* entry point: runs a
  ``[num_rows, tiles_per_window * tile_size]`` window-local schedule slab
  through the device-resident pipeline — the Pallas 2-D-grid kernel
  (``backend="pallas"``) or its bit-identical jnp twin (``"xla"``). Both
  ``kernels/skipper_match/ops.skipper_match`` and the distributed
  matcher's per-device LOCAL PASS (``core/distributed.py``) consume this
  one function, so the two matchers cannot drift.

State encoding is the paper's: ACC=0, MCHD=2 (comparisons below use plain
ints so they work at every ``StateSpec`` width — uint8 at-rest / VMEM state
and the legacy int32 graph alike; ``core/statespec.py`` is the single
source of truth for which tier carries which dtype).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.statespec import StateSpec, resolve as resolve_spec

ACC = 0
MCHD = 2


class StateCell:
    """One mutable state slot with ref-style ``cell[...]`` access — the ONE
    state-cell shim shared by every tile driver (replaces the ad-hoc ``_Row``
    / ``_Cell`` classes that used to live in the pipeline kernel and the two
    ``tile_pass`` variants).

    Backed either by a plain value (``StateCell(value)`` — the jnp tile
    passes thread jax arrays / pytrees through it) or by caller get/set
    closures (``StateCell(get=..., set=...)`` — the Pallas kernels' views
    over VMEM refs, e.g. the (1, W) pipeline block or the (2, W) pair
    scratch). Only whole-cell ``cell[...]`` reads/writes are supported; the
    index is ignored.
    """

    __slots__ = ("_get", "_set", "value")

    def __init__(self, value=None, *, get=None, set=None):
        if get is None:
            self.value = value

            def get():
                return self.value

            def set(v):
                self.value = v

        self._get, self._set = get, set

    def __getitem__(self, _):
        return self._get()

    def __setitem__(self, _, value):
        self._set(value)


def share_matrix(u: jax.Array, v: jax.Array, valid: jax.Array) -> jax.Array:
    """conflict[i, j] = True iff j < i, both valid, and edges i, j share an
    endpoint. TPU-safe: strictly-lower-triangular mask via 2-D iota (Pallas
    TPU requires >= 2-D iota; XLA lowers it identically).

    Args: u/v int32[T] endpoint ids, valid bool[T]. Returns bool[T, T].
    This is the JIT-conflict matrix of DESIGN.md §2 level 0; build it once
    per tile — it is free-mask independent and reused by every round."""
    t = u.shape[0]
    share = (
        (u[:, None] == u[None, :])
        | (u[:, None] == v[None, :])
        | (v[:, None] == u[None, :])
        | (v[:, None] == v[None, :])
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    lower = cols < rows
    return share & lower & valid[None, :] & valid[:, None]


def blocked_from_matrix(conflict: jax.Array) -> Callable[[jax.Array], jax.Array]:
    """``blocked`` predicate from a precomputed ``share_matrix``: edge i is
    blocked iff some FREE j < i shares an endpoint. O(T^2) VPU compares —
    the Pallas kernel's version (T x T ops are native on the VPU and the
    matrix is built once per tile).

    Returns ``blocked_fn(free bool[T]) -> bool[T]`` for
    :func:`first_claim_commit` / :func:`run_first_claim_rounds`. Invariant
    (shared by all three builders, DESIGN.md §3 "Blocked-predicate
    implementations"): ``blocked_fn(free)[i]`` is True iff ``free[i]`` and
    some free ``j < i`` shares an endpoint with edge i — so the returned
    mask is always a subset of ``free``."""

    def blocked_fn(free):
        return jnp.any(conflict & free[None, :], axis=1) & free

    return blocked_fn


def blocked_by_claim_sort(
    u: jax.Array, v: jax.Array, valid: jax.Array, n: int
) -> Callable[[jax.Array], jax.Array]:
    """The same ``blocked`` function, via per-vertex minimum free claimant.

    For each vertex w let ``claimant(w) = min{ j : free_j and w is an
    endpoint of edge j }``; then ``exists free j < i sharing an endpoint``
    is exactly ``min(claimant(u_i), claimant(v_i)) < i`` (edge i itself
    claims at index i, which the strict ``<`` excludes). Computed with one
    sort of the tile's 2T (vertex, edge) slots on a composite int32 key —
    O(T log T) instead of O(T^2), ~2.5x end-to-end on the CPU/XLA matchers.

    The sort happens ONCE per tile (the (vertex, edge) order never changes);
    each round is then O(T): gather the free mask into slot order and
    scatter-min candidate claimants into the per-vertex runs. That keeps
    extra rounds (fallback iterations under vmap pay the batch-max) cheap.

    Requires ``(n + 1) * (T + 1) < 2^31`` (int32 composite key; e.g. n <=
    8M vertices at T = 256) — checked at trace time (a hard raise, not an
    assert: overflow would silently decode wrong claimants under ``-O``).

    Args: u/v int32[T], valid bool[T], n = number of vertices. Returns the
    same ``blocked_fn`` contract as :func:`blocked_from_matrix` (DESIGN.md
    §3 "Blocked-predicate implementations").
    """
    t = u.shape[0]
    if (n + 1) * (t + 1) >= 2**31:
        raise ValueError(
            f"claim-sort int32 key overflow: n={n}, tile={t}; use "
            "conflict_method='matrix' (or 'auto', which picks it)"
        )
    idx = jnp.arange(t, dtype=jnp.int32)
    verts = jnp.concatenate(
        [jnp.where(valid, u, n), jnp.where(valid, v, n)]
    ).astype(jnp.int32)
    eid2 = jnp.concatenate([idx, idx])
    last = 2 * t - 1
    # one sort per tile: slots in (vertex, edge) order
    skey = jnp.sort(verts * (t + 1) + eid2)
    sverts = skey // (t + 1)                     # sorted claimed vertex ids
    seid = (skey % (t + 1)).astype(jnp.int32)    # that slot's edge index
    # run starts: segment id of every sorted slot, and each endpoint's run
    segs = jnp.searchsorted(sverts, sverts)
    pu = jnp.minimum(jnp.searchsorted(sverts, u), last)
    pv = jnp.minimum(jnp.searchsorted(sverts, v), last)
    u_found = sverts[pu] == u
    v_found = sverts[pv] == v

    def blocked_fn(free):
        cand = jnp.where(free[seid], seid, t)    # free slots claim, others inert
        claim = jnp.full((2 * t,), t, jnp.int32).at[segs].min(cand)
        cu = jnp.where(u_found, claim[pu], t)    # min free claimant of u_i
        cv = jnp.where(v_found, claim[pv], t)
        return free & (jnp.minimum(cu, cv) < idx)

    return blocked_fn


def blocked_by_claim_scatter(
    u: jax.Array, v: jax.Array, valid: jax.Array, n: int
) -> Callable[[jax.Array], jax.Array]:
    """Same claimant function as :func:`blocked_by_claim_sort`, via a direct
    scatter-min into a vertex-indexed [n] claim array — no sort, no
    searchsorted. Each round costs one n-element init plus O(T) scatter/
    gather, so it wins when ``n`` is small relative to the tile (the
    window-local tier: ids < window); the sort version wins for
    full-graph-state tiles where the per-round init would dominate.

    Args and contract as :func:`blocked_by_claim_sort` (DESIGN.md §3
    "Blocked-predicate implementations").
    """
    t = u.shape[0]
    idx = jnp.arange(t, dtype=jnp.int32)
    ug = jnp.where(valid, u, 0)
    vg = jnp.where(valid, v, 0)

    def blocked_fn(free):
        cand = jnp.where(free, idx, t)           # only free edges claim
        claim = jnp.full((n,), t, jnp.int32)
        claim = claim.at[ug].min(cand)           # invalid rows write t: inert
        claim = claim.at[vg].min(cand)
        return free & (jnp.minimum(claim[ug], claim[vg]) < idx)

    return blocked_fn


def first_claim_commit(
    su: jax.Array,
    sv: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    blocked_fn: Callable[[jax.Array], jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """One first-claim round. ``su``/``sv`` are the gathered endpoint states;
    ``blocked_fn`` is one of the two blocked implementations above.

    Returns (commit, blocked): ``commit`` edges are mutually endpoint-disjoint
    by construction (the lowest-index free edge of any conflict chain is never
    blocked, so every round makes progress)."""
    free = valid & (~matched) & (su == ACC) & (sv == ACC)
    blocked = blocked_fn(free)
    commit = free & ~blocked
    return commit, blocked


# ---------------------------------------------------------------------------
# Capacitated generalization: first-K-claim rounds (DESIGN.md §9).
#
# The unit-capacity invariant above is the special case cap = 1 of a
# *capacitated* claim rule over two independent id spaces (u-side / v-side,
# e.g. MoE tokens / experts) with per-side budgets:
#
#     room_s(w)  = cap_s - used_s[w]                      (remaining slots)
#     free_i     = valid, undecided, room > 0 on BOTH sides
#     rank_s(i)  = #{ free j < i : side-s id of j == side-s id of i }
#     blocked_i  = rank_u(i) >= room_u(u_i)  or  rank_v(i) >= room_v(v_i)
#     commit_i   = free_i and not blocked_i
#
# rank counts ALL free earlier claimants — including ones that are
# themselves blocked on their other side — so claims cascade exactly as in
# the unit-capacity blocked predicate and the fixpoint of iterated rounds is
# the sequential index-order greedy (greedy_fallback_rounds' proof carries
# over verbatim). With cap_u = cap_v = 1 and disjoint id spaces,
# rank >= room degenerates to "some free j < i claims my endpoint" — the
# paper's reservation step — and the round is bit-identical to
# first_claim_commit (test-pinned, tests/test_bipartite.py).
#
# Like the unit predicate, rank has three interchangeable implementations
# (identical function, picked per side by cost): the triangular same-id
# matrix (O(T^2) VPU/MXU — the TPU-native form), the per-side claim sort
# (one sort per tile, O(T) per round), and the vertex-indexed one-hot prefix
# (O(T*n) per round — wins when the side's id space is tiny, e.g. experts).
# ---------------------------------------------------------------------------


def first_k_claim_commit(
    used_u: jax.Array,
    used_v: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    rank_fn: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    cap_u: int,
    cap_v: int,
) -> Tuple[jax.Array, jax.Array]:
    """One capacitated first-claim round (DESIGN.md §9).

    Args:
        used_u, used_v: int32[T] *gathered per-edge* used counts —
            ``used_u_state[u]``, ``used_v_state[v]``.
        valid, matched: bool[T] as in :func:`first_claim_commit`.
        rank_fn: per-side free-claimant ranks, from
            :func:`capacitated_rank_fn` or one of the ``ranks_*`` builders.
        cap_u, cap_v: static per-side budgets (e.g. ``token_budget``,
            ``expert_capacity``).

    Returns:
        ``(commit, blocked)``. Committed edges never oversubscribe a vertex:
        within one round the commits on any vertex are exactly the free
        claimants with rank < room, so at most ``room`` many. An edge with a
        full endpoint is not free and simply stays unmatched (dead) — no
        explicit kill list is needed.
    """
    room_u = cap_u - used_u.astype(jnp.int32)  # state-dtype: ok (widen at gather)
    room_v = cap_v - used_v.astype(jnp.int32)  # state-dtype: ok (widen at gather)
    free = valid & (~matched) & (room_u > 0) & (room_v > 0)
    rank_u, rank_v = rank_fn(free)
    blocked = free & ((rank_u >= room_u) | (rank_v >= room_v))
    commit = free & ~blocked
    return commit, blocked


def _side_rank_matrix(ids: jax.Array, valid: jax.Array):
    """rank(free)[i] = #{free j < i with ids[j] == ids[i]} via the strictly
    lower-triangular same-id matrix — the per-side analogue of
    :func:`share_matrix` (O(T^2) VPU compares, 2-D iota so it traces inside
    Pallas TPU kernels unchanged)."""
    t = ids.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    mat = (
        (ids[:, None] == ids[None, :])
        & (cols < rows)
        & valid[None, :]
        & valid[:, None]
    )

    def rank(free):
        return jnp.sum((mat & free[None, :]).astype(jnp.int32), axis=1)

    return rank


def _side_rank_sort(ids: jax.Array, valid: jax.Array, n: int):
    """Same rank function via one per-tile sort — the per-side analogue of
    :func:`blocked_by_claim_sort`. Slots sorted once by (id, edge index);
    each round is then a gather + cumsum: rank = exclusive prefix of the
    free mask within the edge's id run. Same int32 composite-key bound."""
    t = ids.shape[0]
    if (n + 1) * (t + 1) >= 2**31:
        raise ValueError(
            f"claim-sort int32 key overflow: n={n}, tile={t}; use "
            "conflict_method='matrix' (or 'auto', which picks it)"
        )
    idx = jnp.arange(t, dtype=jnp.int32)
    masked = jnp.where(valid, ids, n).astype(jnp.int32)
    order = jnp.argsort(masked * (t + 1) + idx)   # unique keys: a total order
    sids = masked[order]
    starts = jnp.searchsorted(sids, sids)          # run start per sorted slot
    pos = jnp.zeros((t,), jnp.int32).at[order].set(idx)  # edge -> sorted slot

    def rank(free):
        fs = free[order].astype(jnp.int32)
        excl = jnp.cumsum(fs) - fs                 # exclusive prefix, global
        return (excl - excl[starts])[pos]          # minus the run's base

    return rank


def _side_rank_scatter(ids: jax.Array, valid: jax.Array, n: int):
    """Same rank function via a vertex-indexed [T, n] one-hot running prefix
    — the capacitated analogue of :func:`blocked_by_claim_scatter`'s dense
    [n] claim array (a min no longer suffices: room > 1 needs the claimant
    *count*). O(T*n) per round, so it wins only when the side's id space is
    tiny relative to the tile — exactly the MoE expert side, where the
    cumsum-of-one-hot is the MXU-friendly form."""
    t = ids.shape[0]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (t, n), 1)
        == jnp.where(valid, ids, n)[:, None]
    )
    col = jnp.minimum(jnp.where(valid, ids, 0), n - 1).astype(jnp.int32)

    def rank(free):
        claims = (onehot & free[:, None]).astype(jnp.int32)
        pref = jnp.cumsum(claims, axis=0) - claims  # exclusive column prefix
        return jnp.take_along_axis(pref, col[:, None], axis=1)[:, 0]

    return rank


_SIDE_RANKS = {
    "matrix": lambda ids, valid, n: _side_rank_matrix(ids, valid),
    "sort": _side_rank_sort,
    "scatter": _side_rank_scatter,
}


def ranks_from_matrix(u: jax.Array, v: jax.Array, valid: jax.Array):
    """Capacitated twin of :func:`blocked_from_matrix`: per-side triangular
    same-id matrices. ``rank_fn(free) -> (rank_u, rank_v)``."""
    ru, rv = _side_rank_matrix(u, valid), _side_rank_matrix(v, valid)
    return lambda free: (ru(free), rv(free))


def ranks_by_claim_sort(
    u: jax.Array, v: jax.Array, valid: jax.Array, n_u: int, n_v: int
):
    """Capacitated twin of :func:`blocked_by_claim_sort`: one sort per side
    per tile, O(T) gathers + a cumsum per round."""
    ru = _side_rank_sort(u, valid, n_u)
    rv = _side_rank_sort(v, valid, n_v)
    return lambda free: (ru(free), rv(free))


def ranks_by_claim_scatter(
    u: jax.Array, v: jax.Array, valid: jax.Array, n_u: int, n_v: int
):
    """Capacitated twin of :func:`blocked_by_claim_scatter`: vertex-indexed
    one-hot prefix per side (use when both id spaces are small)."""
    ru = _side_rank_scatter(u, valid, n_u)
    rv = _side_rank_scatter(v, valid, n_v)
    return lambda free: (ru(free), rv(free))


def capacitated_rank_fn(
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    n_u: int,
    n_v: int,
    method: str = "auto",
):
    """Build the per-side rank function for :func:`first_k_claim_commit`.

    ``method="auto"`` picks *per side* (the sides' id spaces differ wildly in
    the MoE case: thousands of tokens vs a handful of experts): the one-hot
    prefix when the space is tiny, claim-sort while its int32 key fits, the
    T^2 matrix beyond. All three compute the identical function, so the
    choice never changes output (test-pinned, like the unit-capacity trio).
    Explicit ``"matrix"`` / ``"sort"`` / ``"scatter"`` force one
    implementation on both sides."""
    t = u.shape[0]

    def pick(n):
        if n <= max(64, t // 8):
            return "scatter"
        if (n + 1) * (t + 1) < 2**31:
            return "sort"
        return "matrix"

    if method == "auto":
        mu, mv = pick(n_u), pick(n_v)
    elif method in _SIDE_RANKS:
        mu = mv = method
    else:
        raise ValueError(f"unknown conflict_method {method!r}")
    ru = _SIDE_RANKS[mu](u, valid, n_u)
    rv = _SIDE_RANKS[mv](v, valid, n_v)
    return lambda free: (ru(free), rv(free))


def run_first_claim_rounds(
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    read_state: Callable[[], Tuple[jax.Array, jax.Array]],
    apply_commits: Callable[[jax.Array], None],
    vector_rounds: int,
    blocked_fn: Callable[[jax.Array], jax.Array] = None,
    capacities: Optional[Tuple[int, int]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run the unrolled round loop over one tile (DESIGN.md §3 / §9).

    Args:
        u, v: int32[T] endpoint ids of the tile's edges (one shared vertex
            space in the unit-capacity case; two independent id spaces —
            e.g. tokens and experts — in the capacitated case).
        valid: bool[T] — padding / self-loop mask; invalid edges never
            commit, never block, never count.
        read_state: ``() -> (a, b)`` gathers the per-edge endpoint values —
            ``(state[u], state[v])`` for unit capacity, the per-edge *used
            counts* ``(used_u[u], used_v[v])`` when ``capacities`` is given.
            Closes over the caller's state container (a VMEM ref in the
            Pallas kernel, an array cell in jnp callers).
        apply_commits: ``commit -> None`` scatters this round's commits back
            into that container (MCHD to both endpoints / +1 to both used
            counters). Committed edges are mutually claim-disjoint within
            remaining room by construction, so the scatter is conflict-free.
        vector_rounds: number of unrolled rounds. Pure unroll tuning: the
            exact fallback (:func:`greedy_fallback_rounds`) reaches the same
            fixpoint from any unroll depth, so this never changes the output
            — only the conflicts counter and how much work stays out of the
            ``while_loop`` (test-pinned; see DESIGN.md §3 and, for why the
            capacitated default differs, §9).
        blocked_fn: unit capacity — one of the three ``blocked_*`` builders
            (defaults to share-matrix); capacitated — a *rank_fn* from
            :func:`capacitated_rank_fn` / the three ``ranks_*`` builders
            (required: there is no per-side default without the id-space
            sizes).
        capacities: ``None`` (unit capacity — the paper's reservation step)
            or ``(cap_u, cap_v)`` per-side budgets; see
            :func:`first_k_claim_commit`.

    Returns:
        ``(matched bool[T], conflicts int32[T])`` — commits accumulated over
        the rounds and the per-edge blocked-round count (Table II
        instrumentation).

    Invariant (per round): every committed edge was free, and for each of
    its endpoints fewer free lower-index edges claimed that endpoint than it
    had remaining room. The lowest-index free edge always commits, so every
    round makes progress.
    """
    t = u.shape[0]
    if capacities is None:
        if blocked_fn is None:
            blocked_fn = blocked_from_matrix(share_matrix(u, v, valid))

        def commit_round(a, b, matched):
            return first_claim_commit(a, b, valid, matched, blocked_fn)
    else:
        if blocked_fn is None:
            raise ValueError(
                "capacitated rounds need a rank_fn (capacitated_rank_fn)"
            )
        cap_u, cap_v = capacities

        def commit_round(a, b, matched):
            return first_k_claim_commit(
                a, b, valid, matched, blocked_fn, cap_u, cap_v
            )

    matched = jnp.zeros((t,), jnp.bool_)
    conflicts = jnp.zeros((t,), jnp.int32)
    for _ in range(vector_rounds):
        a, b = read_state()
        commit, blocked = commit_round(a, b, matched)
        apply_commits(commit)
        matched = matched | commit
        conflicts = conflicts + blocked.astype(jnp.int32)
    return matched, conflicts


def greedy_fallback_rounds(
    state,
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    blocked_fn: Callable[[jax.Array], jax.Array],
    *,
    gather,
    scatter,
    capacities: Optional[Tuple[int, int]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact vectorized cleanup: iterate first-claim rounds until the tile has
    no free edge left. Returns (state, matched, fallback_taken).

    The fixpoint equals the sequential index-order greedy over the tile's
    remaining edges — the invariant the old scalar-scan fallback enforced.
    Sketch (induction on edge index): after each round every undecided valid
    edge is either free or dead-on-arrival next round (an endpoint out of
    room), so every undecided free edge reserves its claim against all
    higher-index edges; the lowest-index free edge is never blocked, so it
    commits the round it first appears free, and a higher-index edge commits
    only once enough smaller conflicting edges are decided that room remains
    for it — which is exactly the sequential scan's accounting. Every
    iteration commits at least one edge while any is free, so the loop
    terminates in at most T rounds — in practice the depth of the worst
    surviving conflict chain. This holds for unit capacity (room is 0/1,
    MCHD endpoints come only from committed edges) and verbatim for the
    capacitated rule of :func:`first_k_claim_commit` (DESIGN.md §9).

    ``state`` is whatever the caller's gather/scatter understand — the
    vertex-state array for unit capacity, the ``(used_u, used_v)`` counter
    pair (any pytree) when ``capacities=(cap_u, cap_v)`` is given.
    ``gather``/``scatter`` are *pure value* functions (state in, state out) so
    the state threads through the ``while_loop`` carry explicitly — closures
    that mutate a cell would leak tracers across the loop boundary. The
    gathered per-edge values ride the carry too: one gather per iteration (in
    the kernel a gather is two [T, W] MXU matmuls — don't pay it twice).
    """
    if capacities is None:

        def free_mask(a, b, matched):
            return valid & (~matched) & (a == ACC) & (b == ACC)

        def commit_round(a, b, matched):
            return first_claim_commit(a, b, valid, matched, blocked_fn)
    else:
        cap_u, cap_v = capacities

        def free_mask(a, b, matched):
            return valid & (~matched) & (a < cap_u) & (b < cap_v)

        def commit_round(a, b, matched):
            return first_k_claim_commit(
                a, b, valid, matched, blocked_fn, cap_u, cap_v
            )

    def cond(carry):
        return carry[2]

    def body(carry):
        state, matched, _, a, b = carry
        commit, _blocked = commit_round(a, b, matched)
        state = scatter(state, commit)
        matched = matched | commit
        a, b = gather(state)
        go = jnp.any(free_mask(a, b, matched))
        return state, matched, go, a, b

    a, b = gather(state)
    taken = jnp.any(free_mask(a, b, matched))
    state, matched, _, _, _ = jax.lax.while_loop(
        cond, body, (state, matched, taken, a, b)
    )
    return state, matched, taken


def tile_pass(
    state: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    n: int,
    vector_rounds: int,
    fallback: bool = True,
    conflict_method: str = "auto",
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Process one edge tile (first-claim vector rounds + exact vectorized
    fallback, unless ``fallback=False``) against a full ``state`` array of
    ``n`` vertices. Shared by the single-device matcher, the distributed
    local pass / replay, and the device-resident pipeline's boundary
    epilogue (DESIGN.md §1, §3).

    Args:
        state: spec-width[n] vertex states (ACC/MCHD): the pass is
            width-polymorphic — the state keeps the caller's (spec's)
            dtype through gather/scatter, comparisons use plain ints.
        u, v: int32[T] endpoint ids; invalid edges are ``u < 0`` or
            ``u == v`` (pad convention of ``graphs/windows.py``).
        n: static vertex count (shape of ``state``).
        vector_rounds: unrolled rounds before the fallback; pure tuning —
            never changes the output (DESIGN.md §3, test-pinned).
        fallback: run :func:`greedy_fallback_rounds` to the exact greedy
            fixpoint (``False`` only for instrumentation).
        conflict_method: picks the blocked implementation — ``"auto"``
            (default: vertex-indexed claim scatter-min when the state is
            small relative to the tile, claim-sort while its int32 key
            fits, share matrix beyond), ``"scatter"``, ``"sort"``, or
            ``"matrix"`` (the compiled Pallas boundary kernel forces matrix
            because Mosaic has no sort/scatter). All compute the identical
            function, so the choice never changes output.
        spec: optional :class:`StateSpec`. When given, the per-edge
            ``conflicts`` output is narrowed to ``spec.counter`` (exact:
            conflicts <= vector_rounds, validated at trace time). When
            ``None`` conflicts stay in the i32 accumulator width — callers
            that sum conflicts (distributed stats, replay) rely on that.

    Returns:
        ``(state, matched, conflicts_per_edge, fallback_taken)``; every
        valid edge is decided — matched, or dead on an MCHD endpoint (the
        paper's single-pass invariant).

    The capacitated twin (per-side used counts + budgets) is
    :func:`tile_pass_capacitated` (DESIGN.md §9)."""
    valid = (u != v) & (u >= 0)
    t = u.shape[0]
    if conflict_method == "auto":
        if n <= 16 * t:          # per-round claim init is O(n)
            conflict_method = "scatter"
        elif (n + 1) * (t + 1) < 2**31:
            conflict_method = "sort"
        else:                    # beyond the sort key's int32 range
            conflict_method = "matrix"
    if conflict_method == "scatter":
        blocked_fn = blocked_by_claim_scatter(u, v, valid, n)
    elif conflict_method == "sort":
        blocked_fn = blocked_by_claim_sort(u, v, valid, n)
    elif conflict_method == "matrix":
        blocked_fn = blocked_from_matrix(share_matrix(u, v, valid))
    else:
        raise ValueError(f"unknown conflict_method {conflict_method!r}")

    def gather(st):
        return st[jnp.where(valid, u, 0)], st[jnp.where(valid, v, 0)]

    def scatter(st, commit):
        st = st.at[jnp.where(commit, u, n)].set(MCHD, mode="drop")
        return st.at[jnp.where(commit, v, n)].set(MCHD, mode="drop")

    cell = StateCell(state)

    def read_state():
        return gather(cell[...])

    def apply_commits(commit):
        cell[...] = scatter(cell[...], commit)

    matched, conflicts = run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds, blocked_fn
    )
    state = cell[...]
    if spec is not None:
        spec.validate_rounds(vector_rounds)
        conflicts = conflicts.astype(spec.counter_dtype)

    if not fallback:
        return state, matched, conflicts, jnp.zeros((), jnp.bool_)

    state, matched, taken = greedy_fallback_rounds(
        state, u, v, valid, matched, blocked_fn, gather=gather, scatter=scatter
    )
    return state, matched, conflicts, taken


def stream_pass(
    state: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    n: int,
    vector_rounds: int,
    tile_size: int,
    conflict_method: str = "auto",
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy first-claim pass over an [L]-sized edge slab in stream order,
    tiled by ``tile_size`` (``L % tile_size == 0``; -1 marks padding):
    a ``lax.scan`` of :func:`tile_pass` with the state as carry, i.e. the
    sequential single pass over the slab's edges at tile granularity.

    The one slab driver shared by the distributed matcher's LOCAL PASS /
    REPLAY steps (``core/distributed.py``) and the fault-recovery residual
    replay (``core/faults.py``) — the recovery path cannot drift from the
    protocol it recovers.

    Returns ``(state, matched bool[L], conflicts[L])`` — conflicts in the
    i32 accumulator width, or ``spec.counter`` when a spec is passed (see
    :func:`tile_pass`); the state keeps its input (spec) dtype.
    """
    l = u.shape[0]
    num_tiles = l // tile_size
    ut = u.reshape(num_tiles, tile_size)
    vt = v.reshape(num_tiles, tile_size)

    def step(st, uv):
        uu, vv = uv
        st, matched, conflicts, _ = tile_pass(
            st, uu, vv, n=n, vector_rounds=vector_rounds,
            conflict_method=conflict_method, spec=spec,
        )
        return st, (matched, conflicts)

    state, (matched, conflicts) = jax.lax.scan(step, state, (ut, vt))
    return state, matched.reshape(-1), conflicts.reshape(-1)


def tile_pass_pair(
    state_rows: jax.Array,
    u_loc: jax.Array,
    v_loc: jax.Array,
    blk_u: jax.Array,
    blk_v: jax.Array,
    *,
    window: int,
    vector_rounds: int,
    fallback: bool = True,
    conflict_method: str = "auto",
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Two-block variant of :func:`tile_pass` — the block-pair boundary
    epilogue's decision step (DESIGN.md §10).

    Processes one tile of T global-tier edges whose endpoints all live in
    (at most) two vertex-state blocks against ``state_rows`` of shape
    ``[num_windows, window]``: slice out rows ``blk_u`` and ``blk_v``, run
    the standard :func:`tile_pass` on their 2W-element concatenation, write
    the halves back. The endpoint ids are the schedule's *offset-local*
    encoding (``graphs/windows.py``): ``u_loc`` in ``[0, window)`` relative
    to block ``blk_u``; ``v_loc`` relative to block ``blk_v`` **plus
    ``window``** when ``blk_v != blk_u`` and un-offset when the pair is
    same-block — so within the concatenated pair, two slots alias the same
    global vertex iff their local ids are equal, and the pair tile is
    *literally* a ``tile_pass`` over a 2W-vertex state. That is what makes
    the Pallas pair kernel and this jnp form bit-identical by construction:
    both run the identical first-claim rounds + exact fallback on the
    identical local-id tile; only the block load/store differs (DMA +
    one-hot matmuls there, dynamic row slicing here).

    Write-back order is v-half first, u-half second: for a same-block pair
    (``blk_u == blk_v``) every local id is < ``window``, the v-half of the
    concatenation is never read nor written, and the u-half update must win
    the row — with distinct blocks the two updates touch disjoint rows and
    the order is irrelevant.

    Args:
        state_rows: spec-width[num_windows, window] blocked vertex states
            (the pass keeps the caller's dtype).
        u_loc, v_loc: int32[T] offset-local endpoint ids (-1 padding).
        blk_u, blk_v: scalar int32 state-block (window) ids of the pair.
        window / vector_rounds / fallback / conflict_method / spec: as in
            :func:`tile_pass` (``n`` is implied: 2 * window).

    Returns:
        ``(state_rows, matched, conflicts_per_edge, fallback_taken)``.
    """
    row_u = jax.lax.dynamic_index_in_dim(state_rows, blk_u, 0, keepdims=False)
    row_v = jax.lax.dynamic_index_in_dim(state_rows, blk_v, 0, keepdims=False)
    pair = jnp.concatenate([row_u, row_v])
    pair, matched, conflicts, taken = tile_pass(
        pair, u_loc, v_loc, n=2 * window, vector_rounds=vector_rounds,
        fallback=fallback, conflict_method=conflict_method, spec=spec,
    )
    state_rows = jax.lax.dynamic_update_index_in_dim(
        state_rows, pair[window:], blk_v, 0
    )
    state_rows = jax.lax.dynamic_update_index_in_dim(
        state_rows, pair[:window], blk_u, 0
    )
    return state_rows, matched, conflicts, taken


def tile_pass_capacitated(
    used_u: jax.Array,
    used_v: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    cap_u: int,
    cap_v: int,
    vector_rounds: int,
    fallback: bool = True,
    conflict_method: str = "auto",
    spec: Optional[StateSpec] = None,
) -> Tuple[Tuple[jax.Array, jax.Array], jax.Array, jax.Array, jax.Array]:
    """Capacitated twin of :func:`tile_pass` (DESIGN.md §9): process one edge
    tile against per-side used-count states with per-side budgets.

    Args:
        used_u: [n_u] used counts of the u side (e.g. per-token) — the
            used counts are this problem's vertex state, so callers may
            allocate them at the spec's at-rest width when the static
            budgets fit (``StateSpec.validate_capacity``); the rank/room
            comparisons widen to i32 at the gather like everywhere else.
        used_v: [n_v] used counts of the v side (e.g. per-expert).
        u, v: int32[T] per-edge side ids; ``-1`` marks padding (validity is
            ``(u >= 0) & (v >= 0)`` — no ``u != v`` check: the sides are
            independent id spaces, unlike the unipartite :func:`tile_pass`).
        cap_u, cap_v: static per-side budgets.
        vector_rounds / fallback / conflict_method: as in :func:`tile_pass`;
            ``conflict_method`` picks per side when ``"auto"``
            (:func:`capacitated_rank_fn`).

    Returns:
        ``((used_u, used_v), matched, conflicts_per_edge, fallback_taken)``.
        The fixpoint (rounds + fallback) is exactly the sequential
        index-order greedy b-matching over the tile's edges, so scanning
        tiles with the used counts as carry yields the sequential greedy
        over the whole stream (test-pinned against a numpy oracle).
    """
    valid = (u >= 0) & (v >= 0)
    n_u, n_v = used_u.shape[0], used_v.shape[0]
    rank_fn = capacitated_rank_fn(u, v, valid, n_u, n_v, conflict_method)
    ug = jnp.where(valid, u, 0)
    vg = jnp.where(valid, v, 0)

    def gather(st):
        return st[0][ug], st[1][vg]

    def scatter(st, commit):
        uu = st[0].at[jnp.where(commit, u, n_u)].add(1, mode="drop")
        uv = st[1].at[jnp.where(commit, v, n_v)].add(1, mode="drop")
        return uu, uv

    cell = StateCell((used_u, used_v))

    def read_state():
        return gather(cell[...])

    def apply_commits(commit):
        cell[...] = scatter(cell[...], commit)

    matched, conflicts = run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds,
        rank_fn, capacities=(cap_u, cap_v),
    )
    state = cell[...]
    if spec is not None:
        spec.validate_rounds(vector_rounds)
        conflicts = conflicts.astype(spec.counter_dtype)

    if not fallback:
        return state, matched, conflicts, jnp.zeros((), jnp.bool_)

    state, matched, taken = greedy_fallback_rounds(
        state, u, v, valid, matched, rank_fn,
        gather=gather, scatter=scatter, capacities=(cap_u, cap_v),
    )
    return state, matched, conflicts, taken


def window_tier_pass(
    u_rows: jax.Array,   # int32[num_rows, tiles_per_window * tile_size]
    v_rows: jax.Array,   # window-LOCAL ids, -1 padding
    *,
    window: int,
    tiles_per_window: int,
    tile_size: int,
    vector_rounds: int,
    backend: str,
    interpret: bool = True,
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run the window tier of a two-tier schedule: each row is one window's
    dispersed tile stream, matched from an all-ACC window-local state
    (DESIGN.md §3; the distributed consumer is §8 step 1).

    This is the single entry point the device-resident pipeline
    (``kernels/skipper_match/ops.skipper_match``) and the distributed
    matcher's per-device LOCAL PASS share — the two matchers cannot drift.
    ``backend="pallas"`` launches the 2-D-grid revolving-VMEM kernel
    (``build_pipeline_matcher``); ``backend="xla"`` runs the bit-identical
    jnp twin (``ref.make_ref_pipeline`` — a flat scan in the exact grid
    order). Imports are deferred: the kernel modules themselves import this
    module.

    Args:
        u_rows, v_rows: int32[num_rows, tiles_per_window * tile_size]
            window-LOCAL endpoint ids, -1 padding (rows are the dense tier
            of ``graphs/windows.build_window_schedule``).
        window / tiles_per_window / tile_size: the schedule's static shape.
        vector_rounds: forwarded to the per-tile rounds (pure tuning).
        backend: ``"pallas"`` or ``"xla"``.
        interpret: Pallas interpreter flag (ignored by the xla twin).
        spec: optional :class:`StateSpec` (None -> the default). Both
            backends allocate state in ``spec.vmem`` and emit
            matched/conflicts in ``spec.counter``, so the two compiled
            graphs stay dtype-identical, not just value-identical.

    Returns:
        ``(states, matched, conflicts)`` with ``states`` of shape
        ``spec.vmem[num_rows, window]`` and ``matched``/``conflicts``
        ``spec.counter`` of ``u_rows``'s shape (values identical across
        backends and specs, test-pinned).

    Invariant: each row's result depends only on that row's tiles (windows
    are disjoint vertex ranges), which is what lets the distributed matcher
    deal rows to devices with zero communication.
    """
    spec = resolve_spec(spec)
    num_rows = u_rows.shape[0]
    if backend == "pallas":
        from repro.kernels.skipper_match.kernel import build_pipeline_matcher

        call = build_pipeline_matcher(
            num_rows, tiles_per_window, tile_size, window,
            vector_rounds, True, interpret, spec,
        )
        state0 = jnp.zeros((num_rows, window), spec.vmem_dtype)
        states, matched, conflicts = call(u_rows, v_rows, state0)
    elif backend == "xla":
        from repro.kernels.skipper_match.ref import make_ref_pipeline

        run = make_ref_pipeline(window, vector_rounds, spec=spec)
        states, matched, conflicts = run(
            u_rows.reshape(num_rows, tiles_per_window, tile_size),
            v_rows.reshape(num_rows, tiles_per_window, tile_size),
        )
        matched = matched.reshape(u_rows.shape)
        conflicts = conflicts.reshape(u_rows.shape)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return states, matched, conflicts
