"""The shared first-claim engine — Skipper's invariant in ONE place.

Every matcher in this repo (the single-device tiled matcher in
``core/skipper.py``, the shard_map distributed matcher in
``core/distributed.py``, the Pallas TPU kernel in
``kernels/skipper_match/kernel.py`` and its jnp oracle in
``kernels/skipper_match/ref.py``) enforces the same invariant, ported from the
paper's per-edge CAS protocol (Alg. 1):

    every edge is decided (matched / dead) at the moment it is touched, and an
    edge is dead only if one of its endpoints is already MCHD.

The vectorized form of that invariant is the *first-claim round* over a tile
of T edges:

    free_i    = both endpoints ACC and edge undecided
    blocked_i = exists j < i in the tile: free_j and edges i, j share an endpoint
    commit_i  = free_i and not blocked_i      # mutually endpoint-disjoint!

This module owns the pieces that must never drift between matchers. The
``blocked`` predicate has TWO interchangeable implementations computing the
exact same function (tests pin bit-equality across them):

* ``share_matrix`` + ``blocked_from_matrix`` — the triangular
  endpoint-sharing (JIT-conflict) matrix, O(T^2) VPU compares. Built with
  2-D ``broadcasted_iota`` so the exact same code traces inside a Pallas
  TPU kernel and in plain XLA; the T x T work is native MXU/VPU food, which
  is why the compiled kernel keeps it.
* ``blocked_by_claim_sort`` — per-vertex minimum free claimant via one sort
  of the tile's 2T endpoint slots: edge i is blocked iff some free edge
  j < i claims one of its endpoints, i.e. ``min(claimant(u_i),
  claimant(v_i)) < i``. O(T log T) — the CPU/XLA twin's hot-path version
  (~2.5x end-to-end on the jnp matchers, measured rmat14).

``first_claim_commit`` turns gathered endpoint states plus a blocked
predicate into one round's commit/blocked decision. On top sit the standard
drivers:

* ``run_first_claim_rounds`` — the unrolled round loop, parameterized over the
  caller's gather/scatter (the kernel passes MXU one-hot matmuls closing over
  a VMEM ref; jnp callers pass ``.at`` indexing).
* ``greedy_fallback_rounds`` — the exact cleanup of edges that survive the
  unrolled rounds (long conflict chains): iterated first-claim rounds in a
  ``while_loop`` until no free edge remains. The fixpoint is *exactly* the
  sequential index-order greedy matching (see its docstring), so the result
  is identical to a scalar scan of the tile — but each iteration is one
  vectorized round, and under vmap/scan the loop costs only as many
  iterations as the worst surviving chain actually needs (a serial scan
  fallback under vmap degrades to always paying T steps: ``lax.cond``
  becomes ``select`` and runs both branches).
* ``tile_pass`` — the full jnp tile pass (rounds + exact fallback) consumed
  by the single-device and distributed matchers and by the device-resident
  pipeline's boundary epilogue.
* ``window_tier_pass`` — the shared *window tier* entry point: runs a
  ``[num_rows, tiles_per_window * tile_size]`` window-local schedule slab
  through the device-resident pipeline — the Pallas 2-D-grid kernel
  (``backend="pallas"``) or its bit-identical jnp twin (``"xla"``). Both
  ``kernels/skipper_match/ops.skipper_match`` and the distributed
  matcher's per-device LOCAL PASS (``core/distributed.py``) consume this
  one function, so the two matchers cannot drift.

State encoding is the paper's: ACC=0, MCHD=2 (comparisons below use plain
ints so they work for the uint8 at-rest array and the int32 VMEM window
alike).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

ACC = 0
MCHD = 2


def share_matrix(u: jax.Array, v: jax.Array, valid: jax.Array) -> jax.Array:
    """conflict[i, j] = True iff j < i, both valid, and edges i, j share an
    endpoint. TPU-safe: strictly-lower-triangular mask via 2-D iota (Pallas
    TPU requires >= 2-D iota; XLA lowers it identically)."""
    t = u.shape[0]
    share = (
        (u[:, None] == u[None, :])
        | (u[:, None] == v[None, :])
        | (v[:, None] == u[None, :])
        | (v[:, None] == v[None, :])
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    lower = cols < rows
    return share & lower & valid[None, :] & valid[:, None]


def blocked_from_matrix(conflict: jax.Array) -> Callable[[jax.Array], jax.Array]:
    """``blocked`` predicate from a precomputed ``share_matrix``: edge i is
    blocked iff some FREE j < i shares an endpoint. O(T^2) VPU compares —
    the Pallas kernel's version (T x T ops are native on the VPU and the
    matrix is built once per tile)."""

    def blocked_fn(free):
        return jnp.any(conflict & free[None, :], axis=1) & free

    return blocked_fn


def blocked_by_claim_sort(
    u: jax.Array, v: jax.Array, valid: jax.Array, n: int
) -> Callable[[jax.Array], jax.Array]:
    """The same ``blocked`` function, via per-vertex minimum free claimant.

    For each vertex w let ``claimant(w) = min{ j : free_j and w is an
    endpoint of edge j }``; then ``exists free j < i sharing an endpoint``
    is exactly ``min(claimant(u_i), claimant(v_i)) < i`` (edge i itself
    claims at index i, which the strict ``<`` excludes). Computed with one
    sort of the tile's 2T (vertex, edge) slots on a composite int32 key —
    O(T log T) instead of O(T^2), ~2.5x end-to-end on the CPU/XLA matchers.

    The sort happens ONCE per tile (the (vertex, edge) order never changes);
    each round is then O(T): gather the free mask into slot order and
    scatter-min candidate claimants into the per-vertex runs. That keeps
    extra rounds (fallback iterations under vmap pay the batch-max) cheap.

    Requires ``(n + 1) * (T + 1) < 2^31`` (int32 composite key; e.g. n <=
    8M vertices at T = 256) — checked at trace time (a hard raise, not an
    assert: overflow would silently decode wrong claimants under ``-O``).
    """
    t = u.shape[0]
    if (n + 1) * (t + 1) >= 2**31:
        raise ValueError(
            f"claim-sort int32 key overflow: n={n}, tile={t}; use "
            "conflict_method='matrix' (or 'auto', which picks it)"
        )
    idx = jnp.arange(t, dtype=jnp.int32)
    verts = jnp.concatenate(
        [jnp.where(valid, u, n), jnp.where(valid, v, n)]
    ).astype(jnp.int32)
    eid2 = jnp.concatenate([idx, idx])
    last = 2 * t - 1
    # one sort per tile: slots in (vertex, edge) order
    skey = jnp.sort(verts * (t + 1) + eid2)
    sverts = skey // (t + 1)                     # sorted claimed vertex ids
    seid = (skey % (t + 1)).astype(jnp.int32)    # that slot's edge index
    # run starts: segment id of every sorted slot, and each endpoint's run
    segs = jnp.searchsorted(sverts, sverts)
    pu = jnp.minimum(jnp.searchsorted(sverts, u), last)
    pv = jnp.minimum(jnp.searchsorted(sverts, v), last)
    u_found = sverts[pu] == u
    v_found = sverts[pv] == v

    def blocked_fn(free):
        cand = jnp.where(free[seid], seid, t)    # free slots claim, others inert
        claim = jnp.full((2 * t,), t, jnp.int32).at[segs].min(cand)
        cu = jnp.where(u_found, claim[pu], t)    # min free claimant of u_i
        cv = jnp.where(v_found, claim[pv], t)
        return free & (jnp.minimum(cu, cv) < idx)

    return blocked_fn


def blocked_by_claim_scatter(
    u: jax.Array, v: jax.Array, valid: jax.Array, n: int
) -> Callable[[jax.Array], jax.Array]:
    """Same claimant function as :func:`blocked_by_claim_sort`, via a direct
    scatter-min into a vertex-indexed [n] claim array — no sort, no
    searchsorted. Each round costs one n-element init plus O(T) scatter/
    gather, so it wins when ``n`` is small relative to the tile (the
    window-local tier: ids < window); the sort version wins for
    full-graph-state tiles where the per-round init would dominate.
    """
    t = u.shape[0]
    idx = jnp.arange(t, dtype=jnp.int32)
    ug = jnp.where(valid, u, 0)
    vg = jnp.where(valid, v, 0)

    def blocked_fn(free):
        cand = jnp.where(free, idx, t)           # only free edges claim
        claim = jnp.full((n,), t, jnp.int32)
        claim = claim.at[ug].min(cand)           # invalid rows write t: inert
        claim = claim.at[vg].min(cand)
        return free & (jnp.minimum(claim[ug], claim[vg]) < idx)

    return blocked_fn


def first_claim_commit(
    su: jax.Array,
    sv: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    blocked_fn: Callable[[jax.Array], jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """One first-claim round. ``su``/``sv`` are the gathered endpoint states;
    ``blocked_fn`` is one of the two blocked implementations above.

    Returns (commit, blocked): ``commit`` edges are mutually endpoint-disjoint
    by construction (the lowest-index free edge of any conflict chain is never
    blocked, so every round makes progress)."""
    free = valid & (~matched) & (su == ACC) & (sv == ACC)
    blocked = blocked_fn(free)
    commit = free & ~blocked
    return commit, blocked


def run_first_claim_rounds(
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    read_state: Callable[[], Tuple[jax.Array, jax.Array]],
    apply_commits: Callable[[jax.Array], None],
    vector_rounds: int,
    blocked_fn: Callable[[jax.Array], jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run the unrolled round loop over one tile.

    ``read_state()`` gathers (state[u], state[v]); ``apply_commits(commit)``
    scatters MCHD to the endpoints of committed edges — both close over the
    caller's state container (a VMEM ref in the kernel, an array cell in jnp
    callers). ``blocked_fn`` defaults to the share-matrix implementation and
    lets the caller share one instance with the fallback. Returns (matched,
    conflicts_per_edge)."""
    t = u.shape[0]
    if blocked_fn is None:
        blocked_fn = blocked_from_matrix(share_matrix(u, v, valid))
    matched = jnp.zeros((t,), jnp.bool_)
    conflicts = jnp.zeros((t,), jnp.int32)
    for _ in range(vector_rounds):
        su, sv = read_state()
        commit, blocked = first_claim_commit(su, sv, valid, matched, blocked_fn)
        apply_commits(commit)
        matched = matched | commit
        conflicts = conflicts + blocked.astype(jnp.int32)
    return matched, conflicts


def greedy_fallback_rounds(
    state: jax.Array,
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    blocked_fn: Callable[[jax.Array], jax.Array],
    *,
    gather: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    scatter: Callable[[jax.Array, jax.Array], jax.Array],
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact vectorized cleanup: iterate first-claim rounds until the tile has
    no free edge left. Returns (state, matched, fallback_taken).

    The fixpoint equals the sequential index-order greedy over the tile's
    remaining edges — the invariant the old scalar-scan fallback enforced.
    Sketch (induction on edge index): the lowest-index free edge is never
    blocked, so it commits the round it first appears free; a higher-index
    edge commits only once every smaller conflicting edge is decided, and it
    can only die on an MCHD endpoint. MCHD endpoints come only from committed
    edges, which by induction are exactly the greedy winners, so each edge's
    final decision matches the sequential scan. Every iteration commits at
    least one edge while any is free, so the loop terminates in at most T
    rounds — in practice the depth of the worst surviving conflict chain.

    ``gather``/``scatter`` are *pure value* functions (state in, state out) so
    the state threads through the ``while_loop`` carry explicitly — closures
    that mutate a cell would leak tracers across the loop boundary. The
    gathered (su, sv) ride the carry too: one gather per iteration (in the
    kernel a gather is two [T, W] MXU matmuls — don't pay it twice).
    """

    def free_mask(su, sv, matched):
        return valid & (~matched) & (su == ACC) & (sv == ACC)

    def cond(carry):
        return carry[2]

    def body(carry):
        state, matched, _, su, sv = carry
        commit, _blocked = first_claim_commit(su, sv, valid, matched, blocked_fn)
        state = scatter(state, commit)
        matched = matched | commit
        su, sv = gather(state)
        go = jnp.any(free_mask(su, sv, matched))
        return state, matched, go, su, sv

    su, sv = gather(state)
    taken = jnp.any(free_mask(su, sv, matched))
    state, matched, _, _, _ = jax.lax.while_loop(
        cond, body, (state, matched, taken, su, sv)
    )
    return state, matched, taken


def tile_pass(
    state: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    n: int,
    vector_rounds: int,
    fallback: bool = True,
    conflict_method: str = "auto",
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Process one edge tile (first-claim vector rounds + exact vectorized
    fallback, unless ``fallback=False``) against a full ``state`` array of
    ``n`` vertices. Shared by the single-device matcher, the distributed
    local pass / replay, and the device-resident pipeline's boundary
    epilogue.

    ``conflict_method`` picks the blocked implementation — ``"auto"``
    (default: vertex-indexed claim scatter-min when the state is small
    relative to the tile, claim-sort while its int32 key fits, share matrix
    beyond), ``"scatter"``, ``"sort"``, or ``"matrix"`` (the compiled
    Pallas boundary kernel forces it because Mosaic has no sort/scatter).
    All compute the identical function, so the choice never changes output.

    Returns (state, matched, conflicts_per_edge, fallback_taken)."""
    valid = (u != v) & (u >= 0)
    t = u.shape[0]
    if conflict_method == "auto":
        if n <= 16 * t:          # per-round claim init is O(n)
            conflict_method = "scatter"
        elif (n + 1) * (t + 1) < 2**31:
            conflict_method = "sort"
        else:                    # beyond the sort key's int32 range
            conflict_method = "matrix"
    if conflict_method == "scatter":
        blocked_fn = blocked_by_claim_scatter(u, v, valid, n)
    elif conflict_method == "sort":
        blocked_fn = blocked_by_claim_sort(u, v, valid, n)
    elif conflict_method == "matrix":
        blocked_fn = blocked_from_matrix(share_matrix(u, v, valid))
    else:
        raise ValueError(f"unknown conflict_method {conflict_method!r}")

    def gather(st):
        return st[jnp.where(valid, u, 0)], st[jnp.where(valid, v, 0)]

    def scatter(st, commit):
        st = st.at[jnp.where(commit, u, n)].set(MCHD, mode="drop")
        return st.at[jnp.where(commit, v, n)].set(MCHD, mode="drop")

    class _Cell:
        pass

    cell = _Cell()
    cell.state = state

    def read_state():
        return gather(cell.state)

    def apply_commits(commit):
        cell.state = scatter(cell.state, commit)

    matched, conflicts = run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds, blocked_fn
    )
    state = cell.state

    if not fallback:
        return state, matched, conflicts, jnp.zeros((), jnp.bool_)

    state, matched, taken = greedy_fallback_rounds(
        state, u, v, valid, matched, blocked_fn, gather=gather, scatter=scatter
    )
    return state, matched, conflicts, taken


def window_tier_pass(
    u_rows: jax.Array,   # int32[num_rows, tiles_per_window * tile_size]
    v_rows: jax.Array,   # window-LOCAL ids, -1 padding
    *,
    window: int,
    tiles_per_window: int,
    tile_size: int,
    vector_rounds: int,
    backend: str,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run the window tier of a two-tier schedule: each row is one window's
    dispersed tile stream, matched from an all-ACC window-local state.

    This is the single entry point the device-resident pipeline
    (``kernels/skipper_match/ops.skipper_match``) and the distributed
    matcher's per-device LOCAL PASS share. ``backend="pallas"`` launches the
    2-D-grid revolving-VMEM kernel (``build_pipeline_matcher``);
    ``backend="xla"`` runs the bit-identical jnp twin
    (``ref.make_ref_pipeline`` — a flat scan in the exact grid order, uint8
    state). Imports are deferred: the kernel modules themselves import this
    module.

    Returns ``(states, matched, conflicts)`` with ``states`` of shape
    ``[num_rows, window]`` (int32 on the pallas path, uint8 on xla — values
    identical) and ``matched``/``conflicts`` int32 of ``u_rows``'s shape.
    """
    num_rows = u_rows.shape[0]
    if backend == "pallas":
        from repro.kernels.skipper_match.kernel import build_pipeline_matcher

        call = build_pipeline_matcher(
            num_rows, tiles_per_window, tile_size, window,
            vector_rounds, True, interpret,
        )
        state0 = jnp.zeros((num_rows, window), jnp.int32)
        states, matched, conflicts = call(u_rows, v_rows, state0)
    elif backend == "xla":
        from repro.kernels.skipper_match.ref import make_ref_pipeline

        run = make_ref_pipeline(window, vector_rounds)
        states, matched, conflicts = run(
            u_rows.reshape(num_rows, tiles_per_window, tile_size),
            v_rows.reshape(num_rows, tiles_per_window, tile_size),
        )
        matched = matched.reshape(u_rows.shape)
        conflicts = conflicts.reshape(u_rows.shape)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return states, matched, conflicts
