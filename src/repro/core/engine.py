"""The shared first-claim engine — Skipper's invariant in ONE place.

Every matcher in this repo (the single-device tiled matcher in
``core/skipper.py``, the shard_map distributed matcher in
``core/distributed.py``, the Pallas TPU kernel in
``kernels/skipper_match/kernel.py`` and its jnp oracle in
``kernels/skipper_match/ref.py``) enforces the same invariant, ported from the
paper's per-edge CAS protocol (Alg. 1):

    every edge is decided (matched / dead) at the moment it is touched, and an
    edge is dead only if one of its endpoints is already MCHD.

The vectorized form of that invariant is the *first-claim round* over a tile
of T edges:

    free_i    = both endpoints ACC and edge undecided
    blocked_i = exists j < i in the tile: free_j and edges i, j share an endpoint
    commit_i  = free_i and not blocked_i      # mutually endpoint-disjoint!

This module owns the two pieces that must never drift between matchers:

* ``share_matrix``       — the triangular endpoint-sharing (JIT-conflict)
                           matrix. Built with 2-D ``broadcasted_iota`` so the
                           exact same code traces inside a Pallas TPU kernel
                           and in plain XLA.
* ``first_claim_commit`` — one round's commit/blocked decision from gathered
                           endpoint states.

plus the two standard drivers built on them:

* ``run_first_claim_rounds`` — the unrolled round loop, parameterized over the
  caller's gather/scatter (the kernel passes MXU one-hot matmuls closing over
  a VMEM ref; jnp callers pass ``.at`` indexing).
* ``tile_pass`` — the full jnp tile pass (rounds + exact sequential fallback)
  consumed by the single-device and distributed matchers and by the
  device-resident pipeline's boundary epilogue.

State encoding is the paper's: ACC=0, MCHD=2 (comparisons below use plain
ints so they work for the uint8 at-rest array and the int32 VMEM window
alike).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

ACC = 0
MCHD = 2


def share_matrix(u: jax.Array, v: jax.Array, valid: jax.Array) -> jax.Array:
    """conflict[i, j] = True iff j < i, both valid, and edges i, j share an
    endpoint. TPU-safe: strictly-lower-triangular mask via 2-D iota (Pallas
    TPU requires >= 2-D iota; XLA lowers it identically)."""
    t = u.shape[0]
    share = (
        (u[:, None] == u[None, :])
        | (u[:, None] == v[None, :])
        | (v[:, None] == u[None, :])
        | (v[:, None] == v[None, :])
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    lower = cols < rows
    return share & lower & valid[None, :] & valid[:, None]


def first_claim_commit(
    su: jax.Array,
    sv: jax.Array,
    valid: jax.Array,
    matched: jax.Array,
    conflict: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One first-claim round. ``su``/``sv`` are the gathered endpoint states.

    Returns (commit, blocked): ``commit`` edges are mutually endpoint-disjoint
    by construction (the lowest-index free edge of any conflict chain is never
    blocked, so every round makes progress)."""
    free = valid & (~matched) & (su == ACC) & (sv == ACC)
    blocked = jnp.any(conflict & free[None, :], axis=1) & free
    commit = free & ~blocked
    return commit, blocked


def run_first_claim_rounds(
    u: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    read_state: Callable[[], Tuple[jax.Array, jax.Array]],
    apply_commits: Callable[[jax.Array], None],
    vector_rounds: int,
) -> Tuple[jax.Array, jax.Array]:
    """Run the unrolled round loop over one tile.

    ``read_state()`` gathers (state[u], state[v]); ``apply_commits(commit)``
    scatters MCHD to the endpoints of committed edges — both close over the
    caller's state container (a VMEM ref in the kernel, an array cell in jnp
    callers). Returns (matched, conflicts_per_edge)."""
    t = u.shape[0]
    conflict = share_matrix(u, v, valid)
    matched = jnp.zeros((t,), jnp.bool_)
    conflicts = jnp.zeros((t,), jnp.int32)
    for _ in range(vector_rounds):
        su, sv = read_state()
        commit, blocked = first_claim_commit(su, sv, valid, matched, conflict)
        apply_commits(commit)
        matched = matched | commit
        conflicts = conflicts + blocked.astype(jnp.int32)
    return matched, conflicts


def tile_pass(
    state: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    n: int,
    vector_rounds: int,
    fallback: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Process one edge tile (first-claim vector rounds + exact sequential
    fallback, unless ``fallback=False``) against a full ``state`` array of
    ``n`` vertices. Shared by the single-device matcher, the distributed
    local pass / replay, and the device-resident pipeline's boundary
    epilogue.

    Returns (state, matched, conflicts_per_edge, fallback_taken)."""
    valid = (u != v) & (u >= 0)

    class _Cell:
        pass

    cell = _Cell()
    cell.state = state

    def read_state():
        su = cell.state[jnp.where(valid, u, 0)]
        sv = cell.state[jnp.where(valid, v, 0)]
        return su, sv

    def apply_commits(commit):
        st = cell.state
        st = st.at[jnp.where(commit, u, n)].set(MCHD, mode="drop")
        st = st.at[jnp.where(commit, v, n)].set(MCHD, mode="drop")
        cell.state = st

    matched, conflicts = run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds
    )
    state = cell.state

    if not fallback:
        return state, matched, conflicts, jnp.zeros((), jnp.bool_)

    # Exact sequential fallback for pathological chains (rare): guarded so the
    # scan body only runs when some edge is still undecided-and-free.
    su, sv = read_state()
    remaining = valid & (~matched) & (su == ACC) & (sv == ACC)

    def run_fallback(args):
        state, matched = args

        def fstep(st, uvr):
            uu, vv, rem = uvr
            s1 = st[jnp.where(rem, uu, 0)]
            s2 = st[jnp.where(rem, vv, 0)]
            take = rem & (s1 == ACC) & (s2 == ACC)
            st = st.at[jnp.where(take, uu, n)].set(MCHD, mode="drop")
            st = st.at[jnp.where(take, vv, n)].set(MCHD, mode="drop")
            return st, take

        state, extra = jax.lax.scan(fstep, state, (u, v, remaining))
        return state, matched | extra

    state, matched = jax.lax.cond(
        jnp.any(remaining), run_fallback, lambda args: args, (state, matched)
    )
    return state, matched, conflicts, jnp.any(remaining)
