"""Skipper maximal matching — TPU-native adaptation (single device).

The paper's per-edge CAS loop (Alg. 1) has no TPU equivalent: a TPU core runs
one sequential program; there are no asynchronous threads to race, and Pallas
TPU exposes no CAS. What survives the port is the *invariant* the CAS protocol
enforces:

    every edge is decided (matched / dead) at the moment it is touched, and an
    edge is dead only if one of its endpoints is already MCHD.

We enforce the same invariant with vectorized *first-claim* conflict
resolution over VMEM-sized tiles of the edge stream (the round logic itself
lives in ``core/engine.py``, shared with the Pallas kernel and the
distributed matcher):

  tile round (vectorized, VPU):
    free_i    = both endpoints ACC and edge undecided
    blocked_i = ∃ j<i in the tile: free_j and edges i,j share an endpoint
    commit_i  = free_i and not blocked_i       # mutually endpoint-disjoint!
    scatter MCHD to endpoints of committed edges

``blocked`` is the tile-local JIT conflict: the vector analogue of finding a
vertex RSVD and waiting a few cycles. A blocked edge is *not* requeued into
future passes — it is retried in the next unrolled round of the *same tile*
(a few vector ops later), after which either it commits or an endpoint is
MCHD and it dies. The lowest-index free edge of any conflict chain is never
blocked, so each round makes progress; after ``vector_rounds`` rounds the rare
survivors (long dependency chains inside one tile) fall back to an exact
sequential scan guarded by ``lax.cond`` — the analogue of the paper's
worst-case "reduced parallelism only when JIT conflicts happen" (§IV-B).

Single pass over edges: each tile is loaded once; total work
O(|E| + conflicts), state is one uint8 per vertex. Determinism: given the tile
schedule the output is deterministic (unlike the CPU original — see DESIGN.md
§2 assumption log).

Scheduling (``dispersed=True``): the paper's thread-dispersed
locality-preserving schedule (§IV-C) maps onto the vector lanes — lane l of
the tile stream walks its own *contiguous* block of edges (locality
preserved per lane), while the lanes of any one tile sit in blocks far apart
in the stream (dispersed), which is what makes intra-tile endpoint sharing —
the JIT-conflict source — Θ(λ²)-rare. Without it (``dispersed=False``) a tile
holds consecutive edges, and high-locality inputs (grids, paths) conflict on
every chain; that mode exists to reproduce the paper's argument that the
scheduler matters.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ACC, Counters, MatchResult
from repro.core.engine import tile_pass
from repro.core.statespec import StateSpec, resolve as resolve_spec
from repro.graphs.types import EdgeList
from repro.graphs.partition import pad_edges

__all__ = ["skipper", "tile_pass"]


def skipper(
    edges: EdgeList,
    tile_size: int = 512,
    vector_rounds: int = 1,
    with_conflicts: bool = False,
    dispersed: bool = True,
    conflict_method: str = "auto",
    verify: bool = False,
    spec: Optional[StateSpec] = None,
) -> Tuple[MatchResult, Optional[jax.Array]]:
    """Single-pass tiled Skipper. Returns (MatchResult, conflicts_per_edge?).

    conflicts_per_edge (int32[|E|]) is returned when ``with_conflicts`` — the
    Table II instrumentation (number of rounds each edge spent blocked).
    ``conflict_method`` is forwarded to ``engine.tile_pass``'s blocked
    predicate selection (never changes output; see DESIGN.md §3).

    ``spec`` (``core/statespec.StateSpec``) sets the state array's at-rest
    width — the default is the package-wide 1 B/vertex spec, the paper's
    encoding. The engine's conflict counters stay int32 here regardless
    (they are summed per tile; see ``StateSpec`` on accumulator policy).

    ``verify=True`` runs ``core/validate.check_matching`` on the result and
    raises ``RuntimeError`` if it is not a valid maximal matching — a
    host-side self-check (it synchronizes), kept outside the jitted body.
    """
    result, conflicts = _skipper(
        edges, tile_size, vector_rounds, with_conflicts, dispersed,
        conflict_method, resolve_spec(spec),
    )
    if verify:
        from repro.core.validate import check_matching

        chk = check_matching(edges, result.match_mask)
        ok_v, ok_m = (bool(x) for x in jax.device_get(  # host-sync: ok (verify path)
            (chk["valid"], chk["maximal"])
        ))
        if not (ok_v and ok_m):
            raise RuntimeError(
                f"skipper verify=True: matching failed validation "
                f"(valid={ok_v}, maximal={ok_m})"
            )
    return result, conflicts


@partial(
    jax.jit,
    static_argnames=(
        "tile_size", "vector_rounds", "with_conflicts", "dispersed",
        "conflict_method", "spec",
    ),
)
def _skipper(
    edges: EdgeList,
    tile_size: int = 512,
    vector_rounds: int = 1,
    with_conflicts: bool = False,
    dispersed: bool = True,
    conflict_method: str = "auto",
    spec: Optional[StateSpec] = None,
) -> Tuple[MatchResult, Optional[jax.Array]]:
    """The jitted body of :func:`skipper` (verification stays host-side)."""
    n = edges.num_vertices
    m = edges.num_edges
    e = pad_edges(edges.canonical(), tile_size)
    num_tiles = e.num_edges // tile_size
    if dispersed:
        # lane l <- contiguous block l of the stream; tile t = column t.
        ut = e.u.reshape(tile_size, num_tiles).T
        vt = e.v.reshape(tile_size, num_tiles).T
    else:
        ut = e.u.reshape(num_tiles, tile_size)
        vt = e.v.reshape(num_tiles, tile_size)

    init_state = jnp.full((n,), ACC, resolve_spec(spec).at_rest_dtype)

    def tile_step(carry, uv):
        state, loads, stores, fallbacks = carry
        u, v = uv
        state, matched, conflicts, fb = tile_pass(
            state, u, v, n=n, vector_rounds=vector_rounds,
            conflict_method=conflict_method,
        )
        valid = (u != v) & (u >= 0)
        nvalid = jnp.sum(valid).astype(jnp.int32)
        ncommit = jnp.sum(matched).astype(jnp.int32)
        nconf = jnp.sum(conflicts).astype(jnp.int32)
        # loads: round 0 touches every valid edge's 2 endpoints; later rounds
        # only re-touch edges that were blocked (what a real implementation
        # re-reads while "waiting").
        loads = loads + 2 * nvalid + 2 * nconf
        stores = stores + 2 * ncommit
        fallbacks = fallbacks + fb.astype(jnp.int32)
        return (state, loads, stores, fallbacks), (matched, conflicts)

    carry0 = (
        init_state,
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
    )
    (state, loads, stores, _fb), (matched, conflicts) = jax.lax.scan(
        tile_step, carry0, (ut, vt)
    )
    if dispersed:
        # matched[t, l] corresponds to stream index l * num_tiles + t
        mask = matched.T.reshape(-1)[:m]
        conflicts = conflicts.T.reshape(-1)[:m]
    else:
        mask = matched.reshape(-1)[:m]
        conflicts = conflicts.reshape(-1)[:m]
    counters = Counters(
        edge_reads=jnp.asarray(m, jnp.int32),
        state_loads=loads,
        state_stores=stores,
        rounds=jnp.asarray(1, jnp.int32),
    )
    result = MatchResult(match_mask=mask, state=state, counters=counters)
    return result, (conflicts if with_conflicts else None)
