"""EMS-family baselines the paper compares against (§II-C, §II-D).

* ``ems_israeli_itai`` — randomized Endpoints' Mutual Selection [1]: every
  round each vertex selects its minimum-priority live incident edge under a
  fresh random permutation of edge priorities; mutually-selected edges commit;
  repeat. The per-round permutation IS the randomization overhead the paper
  highlights (§III), and we charge it to the counters.
* ``ems_idmm``         — Internally-Deterministic MM [4]: same mutual-selection
  round structure but the priority is the (fixed) edge id, so the output is
  deterministic and no per-round randomization is paid.
* ``sidmm``            — Sampling-based IDMM [7] (GBBS "RandomGreedy"): the
  globally-permuted edge stream is processed in prefix batches; each batch is
  resolved to completion with IDMM rounds. Mirrors SIDMM's work pattern
  (sampling + iterative rounds + per-round vertex passes) without
  materializing subgraphs.

These baselines exist so the benchmarks can reproduce the paper's Table I /
Fig. 7 contrasts: EMS does several passes over live edges plus scatter traffic
per round — the 17-27-accesses-per-edge regime the paper measures for SIDMM.

All are mask-based (no materialized pruning — the paper's footnote 1 allows
"other, probably more efficient methods"; masking *under*-counts EMS memory
traffic, i.e. is conservative in the baselines' favor).

Counters are int32 (sufficient for the <=2^31 accesses of laptop-scale runs).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.types import ACC, MCHD, STATE_DTYPE, Counters, MatchResult
from repro.graphs.types import EdgeList
from repro.graphs.partition import pad_edges

_INF = jnp.iinfo(jnp.int32).max


def _mutual_selection_round(state, u, v, valid, decided, priority, n):
    """One EMS round: vertex-side scatter-min of priorities, mutual commit.

    ``priority`` must be unique over live edges (a permutation or the edge
    index), otherwise two equal-priority edges could both win a vertex.
    Returns (state, newly_matched, live_count).
    """
    su = state[jnp.where(valid, u, 0)]
    sv = state[jnp.where(valid, v, 0)]
    live = valid & (~decided) & (su == ACC) & (sv == ACC)

    pri = jnp.where(live, priority, _INF)
    best = jnp.full((n + 1,), _INF, jnp.int32)
    best = best.at[jnp.where(live, u, n)].min(pri, mode="drop")
    best = best.at[jnp.where(live, v, n)].min(pri, mode="drop")

    sel_u = best[jnp.where(live, u, n)] == pri
    sel_v = best[jnp.where(live, v, n)] == pri
    commit = live & sel_u & sel_v

    state = state.at[jnp.where(commit, u, n)].set(MCHD, mode="drop")
    state = state.at[jnp.where(commit, v, n)].set(MCHD, mode="drop")
    return state, commit, jnp.sum(live)


def _ems(edges: EdgeList, randomize: bool, max_rounds: int = 128) -> MatchResult:
    n = edges.num_vertices
    m = edges.num_edges
    e = edges.canonical()
    idx = jnp.arange(m, dtype=jnp.int32)
    base_key = jax.random.PRNGKey(0)

    def cond(carry):
        _, _, live, rnd, *_ = carry
        return (live > 0) & (rnd < max_rounds)

    def body(carry):
        state, mask, _, rnd, loads, stores, ereads = carry
        if randomize:
            key = jax.random.fold_in(base_key, rnd)
            pri = jax.random.permutation(key, m).astype(jnp.int32)
        else:
            pri = idx
        state, commit, live = _mutual_selection_round(
            state, e.u, e.v, (e.u != e.v) & (e.u >= 0), mask, pri, n
        )
        mask = mask | commit
        m32 = jnp.asarray(m, jnp.int32)
        live32 = live.astype(jnp.int32)
        # per round: rescan all edges (topology), 2 state loads per edge,
        # 2 scatter-min + 2 selection reads per live edge, 2 stores per commit,
        # plus the randomization pass (1 write + 1 read per edge) if enabled.
        ereads = ereads + m32
        loads = loads + 2 * m32 + 4 * live32 + (2 * m32 if randomize else 0)
        stores = stores + 2 * live32 + 2 * jnp.sum(commit).astype(jnp.int32)
        return (state, mask, live, rnd + 1, loads, stores, ereads)

    z = jnp.zeros((), jnp.int32)
    init = (
        jnp.full((n,), ACC, STATE_DTYPE),
        jnp.zeros((m,), jnp.bool_),
        jnp.asarray(1, jnp.int32),
        z,
        z,
        z,
        z,
    )
    state, mask, _, rounds, loads, stores, ereads = jax.lax.while_loop(cond, body, init)
    counters = Counters(edge_reads=ereads, state_loads=loads, state_stores=stores, rounds=rounds)
    return MatchResult(match_mask=mask, state=state, counters=counters)


@jax.jit
def ems_israeli_itai(edges: EdgeList) -> MatchResult:
    return _ems(edges, randomize=True)


@jax.jit
def ems_idmm(edges: EdgeList) -> MatchResult:
    return _ems(edges, randomize=False)


@partial(jax.jit, static_argnames=("batch_size", "seed"))
def sidmm(edges: EdgeList, batch_size: int = 4096, seed: int = 0) -> MatchResult:
    """Sampling/prefix-batched IDMM (the paper's main competitor).

    The edge stream is randomly permuted once (the randomization cost the
    paper highlights), then processed in prefix batches; each batch runs IDMM
    mutual-selection rounds to completion against the global state.
    """
    n = edges.num_vertices
    m = edges.num_edges
    e = pad_edges(edges.canonical(), batch_size)
    mp = e.num_edges
    perm = jax.random.permutation(jax.random.PRNGKey(seed), mp)
    up = e.u[perm]
    vp = e.v[perm]
    num_batches = mp // batch_size
    ub = up.reshape(num_batches, batch_size)
    vb = vp.reshape(num_batches, batch_size)

    def batch_step(carry, uv):
        state, loads, stores, ereads, rounds = carry
        u, v = uv
        valid = (u != v) & (u >= 0)
        idx = jnp.arange(batch_size, dtype=jnp.int32)

        def cond(c):
            _, _, live, _ = c
            return live > 0

        def body(c):
            state, mask, _, stats = c
            state, commit, live = _mutual_selection_round(
                state, u, v, valid, mask, idx, n
            )
            mask = mask | commit
            l, s, er, rd = stats
            b32 = jnp.asarray(batch_size, jnp.int32)
            live32 = live.astype(jnp.int32)
            er = er + b32
            l = l + 2 * b32 + 4 * live32
            s = s + 2 * live32 + 2 * jnp.sum(commit).astype(jnp.int32)
            return (state, mask, live, (l, s, er, rd + 1))

        init = (state, jnp.zeros((batch_size,), jnp.bool_), jnp.asarray(1, jnp.int32),
                (loads, stores, ereads, rounds))
        state, mask, _, (loads, stores, ereads, rounds) = jax.lax.while_loop(cond, body, init)
        return (state, loads, stores, ereads, rounds), mask

    z = jnp.zeros((), jnp.int32)
    # charge the one-time global permutation: 1 read + 1 write per edge slot
    carry0 = (jnp.full((n,), ACC, STATE_DTYPE), 2 * jnp.asarray(mp, jnp.int32), z, z, z)
    (state, loads, stores, ereads, rounds), mask_b = jax.lax.scan(batch_step, carry0, (ub, vb))
    # un-permute the mask back to original edge order
    mask_p = mask_b.reshape(-1)
    mask = jnp.zeros((mp,), jnp.bool_).at[perm].set(mask_p)[:m]
    counters = Counters(edge_reads=ereads, state_loads=loads, state_stores=stores, rounds=rounds)
    return MatchResult(match_mask=mask, state=state, counters=counters)
