"""JIT-conflict accounting — the Table II analogue.

In the TPU adaptation a "JIT conflict" is an edge that was free but blocked by
an earlier in-tile claimant for one vector round (single-device), or a
proposal that lost the cross-device priority replay (distributed). Both are
the moral equivalent of a failing CAS in Alg. 1 lines 11/14.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

_BUCKETS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def conflict_table(conflicts_per_edge: np.ndarray) -> Dict[str, object]:
    """Summarize a per-edge conflict-count array into the paper's Table II
    columns: max per edge, total, #edges with conflicts, avg per conflicting
    edge, and the bucketed distribution (1, 2, 3-4, 5-8, ..., >256)."""
    c = np.asarray(conflicts_per_edge)
    conflicting = c[c > 0]
    total = int(c.sum())
    n_edges = int(conflicting.size)
    dist: List[int] = []
    lo = 1
    for hi in _BUCKETS:
        dist.append(int(((conflicting >= lo) & (conflicting <= hi)).sum()))
        lo = hi + 1
    dist.append(int((conflicting > _BUCKETS[-1]).sum()))
    return {
        "max_cnf_per_edge": int(c.max()) if c.size else 0,
        "total_cnf": total,
        "edges_exp_cnf": n_edges,
        "avg_cnf_per_edge": (total / n_edges) if n_edges else 0.0,
        "distribution": dist,  # buckets: 1,2,3-4,5-8,9-16,...,129-256,>256
        "conflict_ratio": n_edges / max(c.size, 1),
    }
