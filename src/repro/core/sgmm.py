"""Sequential Greedy Maximal Matching (SGMM) — the paper's §II-B baseline and
our correctness oracle.

Iterates edges in order; an edge is selected iff both endpoints are unmarked.
Expressed as a ``lax.scan`` so it is jit-able; semantics are exactly the
sequential algorithm (scan is sequential by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ACC, MCHD, STATE_DTYPE, Counters, MatchResult
from repro.graphs.types import EdgeList


def sgmm(edges: EdgeList) -> MatchResult:
    """Sequential greedy matching over the edge stream (oracle)."""
    n = edges.num_vertices
    e = edges.canonical()

    def step(state, uv):
        u, v = uv
        valid = (u != v) & (u >= 0)
        su = state[jnp.where(valid, u, 0)]
        sv = state[jnp.where(valid, v, 0)]
        take = valid & (su == ACC) & (sv == ACC)
        idx_u = jnp.where(take, u, n)  # n -> dropped
        idx_v = jnp.where(take, v, n)
        state = state.at[idx_u].set(MCHD, mode="drop")
        state = state.at[idx_v].set(MCHD, mode="drop")
        return state, take

    init = jnp.full((n,), ACC, STATE_DTYPE)
    state, mask = jax.lax.scan(step, init, (e.u, e.v))

    m = e.num_edges
    # SGMM per edge: 1 topology read, <=2 state loads, <=2 state stores.
    # The paper reports 0.3-0.8 accesses/edge because CSR lets it skip the
    # remaining neighbors of a matched vertex; our COO stream reads each edge.
    n_matches = jnp.sum(mask)
    counters = Counters(
        edge_reads=jnp.asarray(m, jnp.int32),
        state_loads=jnp.asarray(2 * m, jnp.int32),
        state_stores=2 * n_matches.astype(jnp.int32),
        rounds=jnp.asarray(1, jnp.int32),
    )
    return MatchResult(match_mask=mask, state=state, counters=counters)


sgmm_jit = jax.jit(sgmm, static_argnames=())
