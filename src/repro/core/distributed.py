"""Multi-device Skipper via shard_map — devices play the paper's threads.

Two schedules share one protocol core (``_make_round_fn``):

**Dispersed path** (``reorder="none"``, the paper's §IV-C deal): every edge
block goes through the four-step round below, exactly like a paper thread
scanning its blocks.

**Locality-sharded path** (``reorder=``/``window=``): the edge stream is
renumbered (`graphs/reorder.py`), bucketed into a two-tier
``WindowSchedule`` and partitioned by `graphs/partition.partition_schedule`.
Windows are disjoint vertex-id ranges, so each device resolves its dealt
windows ENTIRELY locally through the device-resident pipeline
(``engine.window_tier_pass`` — the same Pallas kernel / jnp twin
``skipper_match`` runs), with zero proposals and zero replay; ONE O(V)
collective over the per-window states (no topology) then rebuilds the
committed full state everywhere — a width-honest combine in the active
``StateSpec``'s wire dtype (rows are device-disjoint, so ``pmax`` is exact
at any width; the legacy i32 spec keeps the historical ``psum``) — and only
the global tier (cross-window + coalesced sparse-window edges — the
minority after reordering) runs the four-step protocol. Masks come back in original stream order and states in original
vertex ids through the schedule's ``stream_src``/``perm`` round-trip.

Protocol per round (DESIGN.md §2 level 1; paper Alg. 1 adapted to SPMD):

  1. LOCAL PASS — each device greedily matches its next dispersed edge block
     (plus its retry buffer) against its replica of the vertex-state array,
     exactly like a paper thread scanning its blocks. Local commits are
     *proposals* — the analogue of holding RSVD on both endpoints.
  2. GATHER — one all_gather moves the per-device proposal blocks (tiny:
     O(block) ints, no topology) to every device.
  3. REPLAY — every device applies the gathered proposals in the same
     deterministic position-major order with the same first-claim tile pass.
     Winners become MCHD everywhere (the committed state stays replicated-
     consistent); a proposal loses only if an endpoint was taken by an
     earlier-priority winner — i.e. the edge is *dead by MCHD endpoint*,
     Skipper's invariant.
  4. REQUEUE — edges the local pass killed via a *provisional* claim whose
     claimant then lost, and are still free post-replay, enter the retry
     buffer for the next round (the analogue of spinning on RSVD). Θ(λ²)-rare.

Each edge is decided exactly once except the rare requeues: total expected
work O(|E|/D + conflicts) per device, O(|E| + conflicts) aggregate — the
paper's single-pass property at block granularity.

Cross-pod: the all_gather composes over ("pod", "data") axes; proposal bytes
per round are independent of |E| (the paper's "conflict resolution touches no
topology").

Output is deterministic given the schedule — (D, block_size) on the
dispersed path, (window, tile_size, reorder, D, block_size) on the
locality-sharded one; at D=1 the latter is bit-identical to
``skipper_match`` on the same schedule (test-pinned). See DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.types import ACC, MCHD, Counters, MatchResult
from repro.core.engine import stream_pass, window_tier_pass
from repro.core.statespec import DEFAULT, StateSpec, resolve as resolve_spec
from repro.core.faults import (
    CORRUPT,
    FaultPlan,
    corruption_mask,
    detect_residual,
    proposal_drop_mask,
    residual_replay,
)
from repro.core.validate import check_matching
from repro.graphs.types import EdgeList
from repro.graphs.partition import (
    DeviceSchedule,
    dispersed_blocks,
    locality_device_schedule,
    partition_schedule,
)
from repro.graphs.windows import WindowSchedule

# bounded in-protocol escalation: at most this many re-runs with regrown
# knobs before the ladder drops to the residual replay (DESIGN.md §11)
_MAX_ESCALATIONS = 2


@dataclasses.dataclass(frozen=True)
class DistStats:
    """Per-run distributed accounting (aggregated over devices).

    The last four fields are the degradation ledger (DESIGN.md §11) —
    always zero on a healthy ``on_fault="raise"`` run; filled by
    ``on_fault="report"`` (detection only), ``on_fault="recover"`` (what the
    ladder did), and ``verify=True``.
    """

    proposals: jax.Array        # total proposals sent
    lost_proposals: jax.Array   # proposals that lost replay (cross-device JIT conflicts)
    requeued: jax.Array         # edges requeued (spin-wait analogue)
    retry_overflow: jax.Array   # edges dropped by a full retry buffer (must be 0)
    undrained: jax.Array        # retry entries alive after drain rounds (must be 0)
    gathered_bytes: jax.Array   # collective payload BYTES over the run:
    #   int32 proposal-index gathers + the O(V) state assembly in the
    #   active StateSpec's wire width (was `gathered_ints`, an i32 count)
    recovery_attempts: jax.Array | int = 0  # ladder steps that did real work
    residual_edges: jax.Array | int = 0     # valid edges left undecided
    recovered_matches: jax.Array | int = 0  # matches added by the replay
    corrupted_cells: jax.Array | int = 0    # out-of-domain state bytes seen

    @property
    def gathered_ints(self):
        """Deprecated alias (one release): the old i32-word count. The
        payload is no longer all-i32 — prefer :attr:`gathered_bytes`."""
        import warnings

        warnings.warn(
            "DistStats.gathered_ints is deprecated; use gathered_bytes "
            "(the wire payload is no longer uniformly int32)",
            DeprecationWarning, stacklevel=2,
        )
        return self.gathered_bytes // 4

    @property
    def ok(self) -> bool:
        """True iff the must-be-zero invariants actually held: no retry
        overflow (a dropped edge can silently break maximality) and nothing
        left undrained. ``distributed_skipper(on_fault="raise")`` raises on
        the spot; callers running ``on_fault="report"`` must test this flag.

        NOTE: reading the flag synchronizes — it blocks on the device
        computation via one ``jax.device_get`` of both counters (one
        transfer, not one blocking ``int()`` per field)."""
        ovf, und = jax.device_get(  # host-sync: ok (the ONE fetch)
            (self.retry_overflow, self.undrained)
        )
        return int(ovf) == 0 and int(und) == 0

    def raise_if_bad(self) -> None:
        """Raise ``RuntimeError`` if a must-be-zero invariant tripped.
        Synchronizes, like :attr:`ok` (single ``device_get``)."""
        ovf, und = jax.device_get(  # host-sync: ok (the ONE fetch)
            (self.retry_overflow, self.undrained)
        )
        if int(ovf) != 0 or int(und) != 0:
            raise RuntimeError(
                "distributed matching violated its must-be-zero invariants: "
                f"retry_overflow={int(ovf)} (edges dropped by "
                f"a full retry buffer), undrained={int(und)} "
                "(retry entries alive after the drain rounds) — the matching "
                "may be non-maximal. Increase block_size and/or drain_rounds, "
                "or run on_fault='recover' to complete the matching."
            )


def _make_round_fn(
    *,
    n: int,
    mask_len: int,
    axis_name: str,
    num_devices: int,
    vector_rounds: int,
    tile_size: int,
    block: int,
    edge_lookup=None,
    faults: Optional[FaultPlan] = None,
):
    """Build the four-step round body shared by both distributed schedules.

    The carry is ``(state, mask, ru, rv, ri, stats)`` where ``mask`` is a
    bool[mask_len] of replay winners indexed by the per-edge stream index
    carried in ``ri``/the block index arrays, and ``stats`` is the 9-tuple
    ``(props, req, ovf, gbytes, reads, loads_local, loads_replay,
    stores_replay, winners)`` (``gbytes`` counts wire BYTES — proposal
    slots are int32 stream indices/endpoints, 4 B each). Stats marked *local* count only this device's
    REAL edge work — padded sentinel slots (-1) scanned during padding and
    drain rounds contribute nothing — and get psum'd at the end; the replay
    terms are identical on every device (the replay is replicated) and are
    counted once.

    ``edge_lookup``: optional ``(lu, lv)`` replicated int32 arrays mapping a
    stream index to its endpoints. When the dealt stream is STATIC schedule
    data replicated on every device (the locality-sharded global tier: the
    block-pair grouped ``WindowSchedule.boundary_u``/``boundary_v``), a
    proposal is fully identified by its stream index alone — the GATHER
    moves one int per slot instead of three (u, v, idx) and receivers
    reconstruct the endpoints locally. The dispersed path keeps the 3-int
    proposals (its raw stream is sharded, not replicated).

    ``faults``: optional :class:`FaultPlan`, trace-time gated — ``None``
    (the default) adds zero ops. ``drop_proposals`` drops gather slots the
    local pass believes it sent (the silent-loss failure mode: the edge is
    neither replayed nor requeued); ``lose_shard`` swallows one device's
    proposals wholesale; ``truncate_retry`` shrinks the retry buffer's
    effective capacity so requeues overflow.
    """
    cap = block  # retry buffer capacity
    cap_eff = cap
    if faults is not None and faults.truncate_retry is not None:
        cap_eff = min(cap, faults.truncate_retry)
    slab = block + cap
    slab_pad = (-slab) % tile_size
    slab_t = slab + slab_pad
    dmask = None
    if faults is not None and faults.drop_proposals > 0.0:
        dmask = proposal_drop_mask(faults, mask_len)

    def one_round(carry, blk):
        state, mask, ru, rv, ri, stats = carry
        bu, bv, bi = blk

        # 1. LOCAL PASS on [retry ++ block]
        u = jnp.concatenate([ru, bu, jnp.full((slab_pad,), -1, jnp.int32)])
        v = jnp.concatenate([rv, bv, jnp.full((slab_pad,), -1, jnp.int32)])
        idx = jnp.concatenate([ri, bi, jnp.full((slab_pad,), -1, jnp.int32)])
        local_state, proposed, local_conf = stream_pass(
            state, u, v, n=n, vector_rounds=vector_rounds, tile_size=tile_size
        )
        valid = (u >= 0) & (u != v)
        # dead w.r.t. the committed (pre-round) state — permanent
        sgu = state[jnp.clip(u, 0, n - 1)]
        sgv = state[jnp.clip(v, 0, n - 1)]
        dead_global = valid & (~proposed) & ((sgu == MCHD) | (sgv == MCHD))
        dead_prov = valid & (~proposed) & (~dead_global)

        # 2. GATHER proposals; position-major (round-robin across devices)
        # deterministic order. With a replicated stream lookup, a proposal
        # is just its stream index (1 int); otherwise (u, v, idx).
        sent = proposed
        if dmask is not None:
            # FAULT: drop the slot on the wire — this device still believes
            # it proposed (dead_prov stays False), so the edge is lost
            sent = sent & ~dmask[jnp.clip(idx, 0, mask_len - 1)]
        if faults is not None and faults.lose_shard is not None:
            lost = jax.lax.axis_index(axis_name) == (
                faults.lose_shard % num_devices
            )
            sent = sent & ~lost
        pi = jnp.where(sent, idx, -1)
        gi = jax.lax.all_gather(pi, axis_name).T.reshape(-1)  # [D * slab_t]
        if edge_lookup is not None:
            lu, lv = edge_lookup
            live = gi >= 0
            gj = jnp.clip(gi, 0, lu.shape[0] - 1)
            gu = jnp.where(live, lu[gj], -1)
            gv = jnp.where(live, lv[gj], -1)
            round_gbytes = 4 * slab_t * num_devices  # 1 i32 index per slot
        else:
            pu = jnp.where(sent, u, -1)
            pv = jnp.where(sent, v, -1)
            gu = jax.lax.all_gather(pu, axis_name).T.reshape(-1)
            gv = jax.lax.all_gather(pv, axis_name).T.reshape(-1)
            round_gbytes = 3 * 4 * slab_t * num_devices  # (u, v, idx) i32s

        # 3. REPLAY on the committed state (deterministic first-claim order)
        new_state, winners, _ = stream_pass(
            state, gu, gv, n=n, vector_rounds=vector_rounds, tile_size=tile_size
        )
        mask = mask.at[jnp.where(winners, gi, mask_len)].set(True, mode="drop")

        # 4. REQUEUE provisional-dead edges that are still free post-replay
        snu = new_state[jnp.clip(u, 0, n - 1)]
        snv = new_state[jnp.clip(v, 0, n - 1)]
        requeue = dead_prov & (snu == ACC) & (snv == ACC)
        # compact requeued edges to the front of the retry buffer
        order = jnp.argsort(~requeue)  # True (=0 after ~) first
        ru_n = jnp.where(requeue[order], u[order], -1)[:cap]
        rv_n = jnp.where(requeue[order], v[order], -1)[:cap]
        ri_n = jnp.where(requeue[order], idx[order], -1)[:cap]
        if cap_eff < cap:
            # FAULT: truncated retry buffer — entries past the effective
            # capacity are dropped on the floor and counted as overflow
            keep = jnp.arange(cap, dtype=jnp.int32) < cap_eff
            ru_n = jnp.where(keep, ru_n, -1)
            rv_n = jnp.where(keep, rv_n, -1)
            ri_n = jnp.where(keep, ri_n, -1)
        nreq = jnp.sum(requeue)
        overflow = jnp.maximum(nreq - cap_eff, 0)

        # real-work accounting: only valid slots count (padding/sentinel
        # slots scanned during padded slabs and drain rounds are free);
        # requeued edges count again on re-scan, like the single-device
        # matcher's blocked-edge re-reads.
        nvalid = jnp.sum(valid).astype(jnp.int32)
        nconf = jnp.sum(jnp.where(valid, local_conf, 0)).astype(jnp.int32)
        n_props = jnp.sum(proposed).astype(jnp.int32)
        nwin = jnp.sum(winners).astype(jnp.int32)
        # all devices' proposals, read once each by the (replicated) replay
        n_replayed = jnp.sum((gu >= 0) & (gu != gv)).astype(jnp.int32)

        props, req, ovf, gbytes, reads, l_loc, l_rep, s_rep, wins = stats
        stats = (
            props + n_props,
            req + nreq,
            ovf + overflow,
            gbytes + round_gbytes,
            reads + nvalid,
            l_loc + 2 * nvalid + 2 * nconf,
            l_rep + 2 * n_replayed,
            s_rep + 2 * nwin,
            wins + nwin,
        )
        return (new_state, mask, ru_n, rv_n, ri_n, stats), nwin

    return one_round, slab_t


def _zero_stats():
    z = jnp.zeros((), jnp.int32)
    return (z,) * 9


def _drain_blocks(drain_rounds: int, block: int):
    e = jnp.full((drain_rounds, block), -1, jnp.int32)
    return (e, e, e)


def _aggregate_stats(stats, ru, axis_name):
    """Post-drain stats aggregation: psum the per-device entries, count
    undrained retries, pass replicated entries through."""
    props, req, ovf, gbytes, reads, l_loc, l_rep, s_rep, wins = stats
    und = jnp.sum(ru >= 0)
    agg = lambda x: jax.lax.psum(x, axis_name)
    return (
        agg(props),
        agg(req),
        agg(ovf),
        agg(und),
        gbytes,           # identical on every device already
        agg(reads),
        agg(l_loc),
        l_rep,            # replay is replicated: count once
        s_rep,
        wins,
    )


def dispersed_skipper_fn(
    u_blocks: jax.Array,   # [1, R, B] this device's dispersed blocks
    v_blocks: jax.Array,
    i_blocks: jax.Array,   # [1, R, B] global stream indices
    *,
    num_vertices: int,
    num_edges_padded: int,
    axis_name: str,
    num_devices: int,
    vector_rounds: int,
    tile_size: int,
    drain_rounds: int,
    faults: Optional[FaultPlan] = None,
    spec: StateSpec = DEFAULT,
) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, ...]]:
    """Per-device body of the dispersed (raw stream block) schedule. The
    replicated state array lives at ``spec.at_rest`` width (1 B/vertex by
    default — there is no VMEM/wire split on this path: proposals, not
    state, go over the wire)."""
    n = num_vertices
    # shard_map delivers the device-sharded leading axis as size 1: squeeze.
    u_blocks = u_blocks.reshape(u_blocks.shape[-2:])
    v_blocks = v_blocks.reshape(v_blocks.shape[-2:])
    i_blocks = i_blocks.reshape(i_blocks.shape[-2:])
    _, block = u_blocks.shape

    one_round, _ = _make_round_fn(
        n=n,
        mask_len=num_edges_padded,
        axis_name=axis_name,
        num_devices=num_devices,
        vector_rounds=vector_rounds,
        tile_size=tile_size,
        block=block,
        faults=faults,
    )

    state_dt = spec.at_rest_dtype
    state0 = jnp.full((n,), ACC, state_dt)
    if faults is not None and faults.corrupt_state > 0.0:
        # FAULT: out-of-domain bytes in the committed state — the affected
        # vertices look permanently non-free (neither ACC nor MCHD), so
        # every edge on them dies without being decided
        state0 = jnp.where(
            corruption_mask(faults, n), jnp.asarray(CORRUPT, state_dt), state0
        )
    mask0 = jnp.zeros((num_edges_padded,), jnp.bool_)
    empty = jnp.full((block,), -1, jnp.int32)
    carry0 = (state0, mask0, empty, empty, empty, _zero_stats())

    carry, _ = jax.lax.scan(one_round, carry0, (u_blocks, v_blocks, i_blocks))
    # drain: extra rounds with empty blocks until retry buffers settle
    carry, _ = jax.lax.scan(one_round, carry, _drain_blocks(drain_rounds, block))

    state, mask, ru, _, _, stats = carry
    return state, mask, _aggregate_stats(stats, ru, axis_name)


def locality_sharded_fn(
    u_rows: jax.Array,     # [1, rows_per_device, slots] window-local ids
    v_rows: jax.Array,
    row_slot: jax.Array,   # [1, rows_per_device] schedule-row index, -1 pad
    bu_blocks: jax.Array,  # [1, R, B] global-tier deal (renumbered GLOBAL ids)
    bv_blocks: jax.Array,
    bi_blocks: jax.Array,  # [1, R, B] boundary stream positions
    window_ids: jax.Array,  # int32[num_rows] row -> window id (replicated)
    boundary_lu: jax.Array,  # int32[nb_pad] stream-position -> u (replicated)
    boundary_lv: jax.Array,  #   ... -> v: the idx-only proposal lookup
    *,
    window: int,
    tiles_per_window: int,
    tile_size: int,
    num_rows: int,
    num_windows: int,
    num_boundary_padded: int,
    axis_name: str,
    num_devices: int,
    vector_rounds: int,
    drain_rounds: int,
    backend: str,
    interpret: bool,
    faults: Optional[FaultPlan] = None,
    spec: StateSpec = DEFAULT,
):
    """Per-device body of the locality-sharded schedule.

    PHASE A (window tier, zero communication): this device's dealt window
    rows run through the device-resident pipeline — the identical
    ``engine.window_tier_pass`` entry point ``skipper_match`` uses, so each
    window's result is bit-identical to the single-device pipeline no matter
    which device it was dealt to. One ``spec.combine_rows`` collective over
    the per-row states (disjoint row slots; O(num_rows * window) *
    ``spec.wire_bytes`` bytes, no topology) rebuilds the committed full
    state on every device — max-combine is exact because each row has at
    most one non-zero contributor, and ``lose_shard`` zeroing composes
    (zeros lose to real values).

    PHASE B (global tier): the boundary blocks run the four-step
    propose/gather/replay protocol against that committed state — same
    rounds, seeded with the window-tier commits instead of all-ACC. The
    dealt stream is the replicated block-pair grouped schedule data, so
    proposals gather as bare stream indices (``edge_lookup``): 1 gathered
    int per slot instead of 3.

    Returns (flat committed state [replicated], this device's window-tier
    matched slab [sharded], boundary winners mask [replicated], stats).
    """
    u_rows = u_rows.reshape(u_rows.shape[-2:])
    v_rows = v_rows.reshape(v_rows.shape[-2:])
    row_slot = row_slot.reshape(row_slot.shape[-1:])
    bu_blocks = bu_blocks.reshape(bu_blocks.shape[-2:])
    bv_blocks = bv_blocks.reshape(bv_blocks.shape[-2:])
    bi_blocks = bi_blocks.reshape(bi_blocks.shape[-2:])
    n_flat = num_windows * window

    # ---- PHASE A: device-resident window tier (no collectives) ----------
    states, matched_w, conf_w = window_tier_pass(
        u_rows, v_rows,
        window=window,
        tiles_per_window=tiles_per_window,
        tile_size=tile_size,
        vector_rounds=vector_rounds,
        backend=backend,
        interpret=interpret,
        spec=spec,
    )
    w_valid = u_rows >= 0
    if faults is not None and faults.lose_shard is not None:
        # FAULT: lost shard — this device's whole window-tier contribution
        # (state rows AND matched bits, kept consistent) vanishes before the
        # psum; its global-tier proposals are swallowed in _make_round_fn
        lost = jax.lax.axis_index(axis_name) == (
            faults.lose_shard % num_devices
        )
        states = jnp.where(lost, jnp.zeros_like(states), states)
        matched_w = jnp.where(lost, jnp.zeros_like(matched_w), matched_w)
    # assemble the committed full state: scatter this device's rows into
    # schedule-row order (disjoint across devices), combine at the spec's
    # wire width, then place rows at their window ids (two-tier compaction;
    # coalesced windows stay all-ACC — their edges are global-tier).
    wire_dt = spec.wire_dtype
    slot = jnp.where(row_slot >= 0, row_slot, num_rows)
    rows_state = (
        jnp.zeros((num_rows, window), wire_dt)
        .at[slot].set(states.astype(wire_dt), mode="drop")
    )
    rows_state = spec.combine_rows(rows_state, axis_name)
    flat = (
        jnp.zeros((num_windows, window), wire_dt)
        .at[window_ids].set(rows_state)
        .reshape(n_flat)
        .astype(spec.at_rest_dtype)
    )
    if faults is not None and faults.corrupt_state > 0.0:
        # FAULT: corrupt the assembled committed state (renumbered-flat id
        # space) before the global tier reads it — identical injection site
        # to the single-device pipeline's
        flat = jnp.where(
            corruption_mask(faults, n_flat),
            jnp.asarray(CORRUPT, spec.at_rest_dtype),
            flat,
        )

    # ---- PHASE B: global tier via propose/gather/replay -----------------
    num_rounds, block = bu_blocks.shape
    nvalid_w = jnp.sum(w_valid).astype(jnp.int32)
    # counters may be spec-narrowed (uint8): widen BEFORE summing so a
    # window tier with >255 conflicts/matches can't wrap the stats
    nconf_w = jnp.sum(
        jnp.where(w_valid, conf_w.astype(jnp.int32), 0)
    ).astype(jnp.int32)
    # stores of the window tier happen per device; the stores slot of the
    # stats tuple is a count-once (replicated) entry, so pre-psum here.
    nmatch_w = jax.lax.psum(
        jnp.sum(
            jnp.where(w_valid, matched_w.astype(jnp.int32), 0)
        ).astype(jnp.int32),
        axis_name,
    )
    z = jnp.zeros((), jnp.int32)
    state_wire_bytes = jnp.asarray(
        num_devices * num_rows * window * spec.wire_bytes, jnp.int32
    )  # the PHASE A combine payload — O(V) at wire width, no topology
    stats0 = (z, z, z, state_wire_bytes, nvalid_w,
              2 * nvalid_w + 2 * nconf_w, z, 2 * nmatch_w, z)

    if num_rounds > 0:
        one_round, _ = _make_round_fn(
            n=n_flat,
            mask_len=num_boundary_padded,
            axis_name=axis_name,
            num_devices=num_devices,
            vector_rounds=vector_rounds,
            tile_size=tile_size,
            block=block,
            edge_lookup=(boundary_lu, boundary_lv),
            faults=faults,
        )
        mask0 = jnp.zeros((num_boundary_padded,), jnp.bool_)
        empty = jnp.full((block,), -1, jnp.int32)
        carry0 = (flat, mask0, empty, empty, empty, stats0)
        carry, _ = jax.lax.scan(
            one_round, carry0, (bu_blocks, bv_blocks, bi_blocks)
        )
        carry, _ = jax.lax.scan(
            one_round, carry, _drain_blocks(drain_rounds, block)
        )
        flat, bmask, ru, _, _, stats = carry
    else:
        bmask = jnp.zeros((num_boundary_padded,), jnp.bool_)
        ru = jnp.full((1,), -1, jnp.int32)
        stats = stats0

    stats_out = _aggregate_stats(stats, ru, axis_name)
    matched_out = jnp.where(w_valid, matched_w.astype(jnp.int32), 0)
    return (
        flat,
        matched_out.reshape((1,) + matched_out.shape),
        bmask,
        stats_out,
    )


@lru_cache(maxsize=32)
def _compiled_dispersed(
    mesh, axis_name, num_devices, num_vertices, num_edges_padded,
    vector_rounds, tile_size, drain_rounds, faults=None, spec=DEFAULT,
):
    """One compiled shard_map per static config — rebuilding shard_map+jit
    per call would retrace/recompile every time (~100x the actual run time
    on the bench graphs). Mesh is hashable and participates in the key, as
    do the (frozen, default-None) fault plan and the (frozen) state spec."""
    fn = partial(
        dispersed_skipper_fn,
        num_vertices=num_vertices,
        num_edges_padded=num_edges_padded,
        axis_name=axis_name,
        num_devices=num_devices,
        vector_rounds=vector_rounds,
        tile_size=tile_size,
        drain_rounds=drain_rounds,
        faults=faults,
        spec=spec,
    )
    shard = compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(None), P(None), (P(),) * 10),
        check_vma=False,
    )
    return jax.jit(shard)


@lru_cache(maxsize=32)
def _compiled_sharded(
    mesh, axis_name, num_devices, window, tiles_per_window, tile_size,
    num_rows, num_windows, num_boundary_padded, vector_rounds, drain_rounds,
    backend, interpret, faults=None, spec=DEFAULT,
):
    """Compiled locality-sharded body per static schedule shape (the
    schedule ARRAYS are runtime inputs, including window_ids); the frozen
    fault plan (default None) and the frozen state spec are part of the
    static key."""
    fn = partial(
        locality_sharded_fn,
        window=window,
        tiles_per_window=tiles_per_window,
        tile_size=tile_size,
        num_rows=num_rows,
        num_windows=num_windows,
        num_boundary_padded=num_boundary_padded,
        axis_name=axis_name,
        num_devices=num_devices,
        vector_rounds=vector_rounds,
        drain_rounds=drain_rounds,
        backend=backend,
        interpret=interpret,
        faults=faults,
        spec=spec,
    )
    shard = compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axis_name),) * 6 + (P(None), P(None), P(None)),
        out_specs=(P(None), P(axis_name), P(None), (P(),) * 10),
        check_vma=False,
    )
    return jax.jit(shard)


def _mesh_and_devices(mesh: Optional[Mesh], axis_name: str):
    if mesh is None:
        devs = jax.devices()
        mesh = compat.make_mesh((len(devs),), (axis_name,))
    if isinstance(mesh.shape, dict):
        num_devices = mesh.shape[axis_name]
    else:  # pragma: no cover
        num_devices = dict(zip(mesh.axis_names, mesh.shape))[axis_name]
    return mesh, num_devices


def _finalize(mask, state, stats):
    """Shared host-level epilogue: counters + stats assembly (no policy —
    ``_apply_policy`` owns raising / recovering / reporting)."""
    props, req, ovf, und, gbytes, reads, l_loc, l_rep, s_rep, wins = stats
    lost = props - wins  # proposals that did not win the replay
    counters = Counters(
        edge_reads=reads.astype(jnp.int32),
        state_loads=(l_loc + l_rep).astype(jnp.int32),
        state_stores=s_rep.astype(jnp.int32),
        rounds=jnp.asarray(1, jnp.int32),
    )
    result = MatchResult(match_mask=mask, state=state, counters=counters)
    dstats = DistStats(
        proposals=props,
        lost_proposals=lost,
        requeued=req,
        retry_overflow=ovf,
        undrained=und,
        gathered_bytes=gbytes,
    )
    return result, dstats


def _effective_knobs(block_size, drain_rounds, faults):
    """The (retry capacity, drain rounds) a run ACTUALLY gets once the fault
    plan has had its say — the ladder stops escalating a knob the plan pins
    (regrowing a buffer the plan truncates right back is wasted work)."""
    cap = block_size
    if faults is not None and faults.truncate_retry is not None:
        cap = min(cap, faults.truncate_retry)
    dr = 0 if (faults is not None and faults.skip_drain) else drain_rounds
    return cap, dr


def _apply_policy(
    run,
    edges: Optional[EdgeList],
    *,
    on_fault: str,
    verify: bool,
    faults: Optional[FaultPlan],
    block_size: int,
    drain_rounds: int,
    tile_size: int,
    vector_rounds: int,
    spec: StateSpec = DEFAULT,
) -> Tuple[MatchResult, DistStats]:
    """The recovery ladder (DESIGN.md §11), shared by both schedules.

    ``run(block_size, drain_rounds) -> (MatchResult, DistStats)`` re-executes
    the protocol under escalated knobs (the sharded closure repartitions the
    global-tier deal, the dispersed one re-deals the stream).

    Policy:
      * ``"raise"``  — the historical hard-fail: ``raise_if_bad()``.
      * ``"report"`` — never raise; fill ``residual_edges`` /
        ``corrupted_cells`` so the caller sees the damage (synchronizes).
      * ``"recover"`` — rung 1: up to ``_MAX_ESCALATIONS`` re-runs,
        geometrically regrowing whichever knob tripped (retry capacity on
        ``retry_overflow``, drain rounds on ``undrained``), skipped when the
        fault plan pins the knob; rung 2: ``faults.residual_replay`` —
        rebuild state from the (always-valid) match mask and complete the
        matching over the residual edges. Provably valid+maximal.

    ``verify=True`` additionally runs ``check_matching`` on the final mask
    (raises on failure under every policy — after ``"recover"`` a failure
    is a bug in the ladder itself, and the error says so).
    """
    if on_fault not in ("raise", "recover", "report"):
        raise ValueError(
            f"on_fault must be 'raise', 'recover' or 'report', got {on_fault!r}"
        )
    if (verify or on_fault in ("recover", "report")) and edges is None:
        raise ValueError(
            "on_fault='recover'/'report' and verify=True need the original "
            "edge list — pass edges even when a prebuilt schedule is given"
        )

    bs, dr = block_size, drain_rounds
    result, dstats = run(bs, dr)
    if on_fault == "raise":
        if not verify:
            dstats.raise_if_bad()
        # with verify the check below subsumes raise_if_bad and reports the
        # actual damage, not just the tripwire
    elif on_fault == "recover":
        attempts = 0
        for _ in range(_MAX_ESCALATIONS):
            ovf, und = jax.device_get(  # host-sync: ok (ladder gate)
                (dstats.retry_overflow, dstats.undrained)
            )
            if int(ovf) == 0 and int(und) == 0:
                break
            nbs = bs * 2 if int(ovf) > 0 else bs
            ndr = max(1, dr) * 2 if int(und) > 0 else dr
            if _effective_knobs(nbs, ndr, faults) == _effective_knobs(
                bs, dr, faults
            ):
                break  # the fault pins the knob — go straight to the replay
            bs, dr = nbs, ndr
            attempts += 1
            result, dstats = run(bs, dr)
        mask, state, residual, recovered, corrupted = residual_replay(
            edges, result.match_mask, result.state,
            tile_size=tile_size, vector_rounds=vector_rounds, spec=spec,
        )
        res_i, cor_i = jax.device_get((residual, corrupted))  # host-sync: ok (ladder gate)
        if int(res_i) > 0 or int(cor_i) > 0:
            attempts += 1  # the replay rung did real work
        result = MatchResult(
            match_mask=mask, state=state, counters=result.counters
        )
        dstats = dataclasses.replace(
            dstats,
            recovery_attempts=jnp.asarray(attempts, jnp.int32),
            residual_edges=residual,
            recovered_matches=recovered,
            corrupted_cells=corrupted,
        )

    if on_fault == "report" or (verify and on_fault == "raise"):
        residual, corrupted = detect_residual(
            edges, result.match_mask, result.state
        )
        dstats = dataclasses.replace(
            dstats, residual_edges=residual, corrupted_cells=corrupted
        )

    if verify:
        chk = check_matching(edges, result.match_mask)
        ok_v, ok_m, res_i, cor_i = (
            int(x) for x in jax.device_get(  # host-sync: ok (verify path)
                (chk["valid"], chk["maximal"],
                 dstats.residual_edges, dstats.corrupted_cells)
            )
        )
        if on_fault == "recover" and not (ok_v and ok_m):
            raise RuntimeError(
                "verify=True after on_fault='recover': recovered matching "
                f"failed validation (valid={bool(ok_v)}, maximal={bool(ok_m)})"
                " — this is a bug in the recovery ladder, please report it"
            )
        if on_fault == "raise" and not (ok_v and ok_m and res_i == 0
                                        and cor_i == 0):
            raise RuntimeError(
                "verify=True: matching failed validation "
                f"(valid={bool(ok_v)}, maximal={bool(ok_m)}, "
                f"residual_edges={res_i}, corrupted_cells={cor_i}) — run "
                "on_fault='recover' to complete it or 'report' to inspect"
            )
    return result, dstats


def distributed_skipper(
    edges: Optional[EdgeList] = None,
    mesh: Optional[Mesh] = None,
    axis_name: str = "data",
    block_size: int = 512,
    vector_rounds: int = 1,
    tile_size: int = 256,
    drain_rounds: int = 4,
    reorder: str = "none",
    window: Optional[int] = None,
    schedule: Optional[WindowSchedule] = None,
    device_schedule: Optional[DeviceSchedule] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    on_fault: str = "raise",
    verify: bool = False,
    faults: Optional[FaultPlan] = None,
    spec: Optional[StateSpec] = None,
) -> Tuple[MatchResult, DistStats]:
    """Run Skipper across the devices of ``mesh`` along ``axis_name``.

    Works for any device count >= 1. With the default ``reorder="none"`` /
    ``window=None`` the raw stream is dealt in dispersed blocks (paper
    §IV-C); passing ``reorder=`` (a ``graphs/reorder.py`` policy) and/or
    ``window=`` switches to the locality-sharded schedule: each device's
    intra-window edges run through the device-resident pipeline
    (``engine.window_tier_pass`` — Pallas on TPU, its jnp twin under
    ``backend="xla"``) with zero communication, and only the global tier
    pays the propose/gather/replay protocol. A prebuilt ``schedule`` /
    ``device_schedule`` skips the host precompute (benchmarks).

    Results are always in the ORIGINAL edge-stream order and vertex ids; at
    D=1 the locality-sharded output is bit-identical to
    ``skipper_match(schedule=..., backend=...)`` (test-pinned).

    Failure handling (DESIGN.md §11): ``on_fault`` replaces the old boolean
    ``check=``.

    * ``"raise"`` (default, == the old ``check=True``): ``RuntimeError`` if
      a must-be-zero invariant tripped (``retry_overflow``/``undrained`` —
      a dropped or undecided edge can break maximality).
    * ``"report"`` (== the old ``check=False``, plus detection): never
      raise; the returned :class:`DistStats` carries ``residual_edges`` /
      ``corrupted_cells`` for inspection. Needs ``edges``. Synchronizes.
    * ``"recover"``: bounded in-protocol escalation (regrow the retry
      buffer / drain rounds, at most ``_MAX_ESCALATIONS`` re-runs), then a
      host-side residual replay that provably completes the matching —
      the result is always valid+maximal on the uncorrupted graph, though
      possibly a *different* maximal matching than a fault-free run's.
      Needs ``edges``.

    ``verify=True`` runs ``core/validate.check_matching`` on the final mask
    (and fills the DistStats degradation fields); ``faults=`` threads a
    :class:`FaultPlan` into the compiled bodies for chaos testing —
    ``None`` (default) compiles to exactly the pre-fault-harness graph.

    ``spec=`` (a ``core/statespec.StateSpec``, default the package-wide
    uint8 default) sets the per-tier state widths: the at-rest/replicated
    arrays, the window tier's VMEM carry, and the PHASE A state-assembly
    wire payload. ``StateSpec.legacy_i32()`` reproduces the pre-spec
    int32+psum graph bit-for-bit (test-pinned).
    """
    mesh, num_devices = _mesh_and_devices(mesh, axis_name)
    spec = resolve_spec(spec)
    if faults is not None and not faults.active:
        faults = None  # all sites off: share the clean compiled body
    drain_eff = 0 if (faults is not None and faults.skip_drain) else None

    sharded = (
        reorder != "none"
        or window is not None
        or schedule is not None
        or device_schedule is not None
    )
    if not sharded:
        if edges is None:
            raise ValueError("the dispersed schedule needs an edge list")

        def run_dispersed(bs, dr):
            return _dispersed_skipper(
                edges, mesh, axis_name, num_devices, bs, vector_rounds,
                tile_size, dr if drain_eff is None else drain_eff, faults,
                spec,
            )

        return _apply_policy(
            run_dispersed, edges,
            on_fault=on_fault, verify=verify, faults=faults,
            block_size=block_size, drain_rounds=drain_rounds,
            tile_size=tile_size, vector_rounds=vector_rounds, spec=spec,
        )

    if device_schedule is None:
        if schedule is None and edges is None:
            raise ValueError("need edges or a prebuilt (device) schedule")
        device_schedule = locality_device_schedule(
            edges, num_devices, block_size,
            window=window, tile_size=tile_size, reorder=reorder,
            schedule=schedule,
        )
    schedule = device_schedule.schedule
    if device_schedule.num_devices != num_devices:
        raise ValueError(
            f"device_schedule was partitioned for {device_schedule.num_devices} "
            f"devices, mesh has {num_devices}"
        )
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    ds0, bs0 = device_schedule, device_schedule.block_size

    def run_sharded(bs, dr):
        # escalated retry capacity == escalated global-tier block size:
        # repartition the SAME WindowSchedule (host-cheap — the window tier
        # deal is unchanged in content, only the boundary blocks re-deal)
        ds = ds0 if bs == bs0 else partition_schedule(
            schedule, num_devices, bs
        )
        return _sharded_run(
            ds, mesh, axis_name, num_devices, vector_rounds,
            dr if drain_eff is None else drain_eff, backend,
            bool(interpret), faults, spec,
        )

    return _apply_policy(
        run_sharded, edges,
        on_fault=on_fault, verify=verify, faults=faults,
        block_size=bs0, drain_rounds=drain_rounds,
        tile_size=tile_size, vector_rounds=vector_rounds, spec=spec,
    )


def _sharded_run(
    device_schedule, mesh, axis_name, num_devices, vector_rounds,
    drain_rounds, backend, interpret, faults, spec=DEFAULT,
):
    """One locality-sharded execution + host epilogue (no policy)."""
    schedule = device_schedule.schedule
    slots = schedule.tiles_per_window * schedule.tile_size
    num_rows = schedule.num_rows
    run = _compiled_sharded(
        mesh, axis_name, num_devices, schedule.window,
        schedule.tiles_per_window, schedule.tile_size, num_rows,
        schedule.num_windows, schedule.num_boundary_padded, vector_rounds,
        drain_rounds, backend, interpret, faults, spec,
    )
    flat, matched_w, bmask, stats = run(
        jnp.asarray(device_schedule.u_rows),
        jnp.asarray(device_schedule.v_rows),
        jnp.asarray(device_schedule.row_slot),
        jnp.asarray(device_schedule.boundary_ub),
        jnp.asarray(device_schedule.boundary_vb),
        jnp.asarray(device_schedule.boundary_ib),
        jnp.asarray(schedule.window_ids),
        jnp.asarray(schedule.boundary_u),
        jnp.asarray(schedule.boundary_v),
    )

    # ---- host epilogue: decisions -> stream order, state -> original ids
    # (the same [windowed ++ global ++ pad] slot layout and stream_src
    # gather skipper_match uses)
    slot_flat = np.where(
        device_schedule.row_slot.reshape(-1) >= 0,
        device_schedule.row_slot.reshape(-1),
        num_rows,
    )
    dec_w = (
        jnp.zeros((num_rows, slots), jnp.int32)
        .at[jnp.asarray(slot_flat)]
        .set(matched_w.reshape(-1, slots), mode="drop")
    )
    decisions = jnp.concatenate(
        [dec_w.reshape(-1), bmask.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
    )
    mask = decisions[jnp.asarray(schedule.stream_src)] > 0
    perm = schedule.perm
    if perm is None:
        perm = np.arange(schedule.num_vertices, dtype=np.int32)
    state = flat[jnp.asarray(perm)].astype(spec.at_rest_dtype)
    return _finalize(mask, state, stats)


def _dispersed_skipper(
    edges, mesh, axis_name, num_devices, block_size, vector_rounds,
    tile_size, drain_rounds, faults, spec=DEFAULT,
):
    """One raw dispersed-block execution (paper §IV-C), D >= 1 (no policy)."""
    n = edges.num_vertices
    m = edges.num_edges
    e = edges.canonical()
    ub, vb = dispersed_blocks(e, num_devices, block_size)  # [D, R, B]
    num_rounds = ub.shape[1]
    num_edges_padded = num_devices * num_rounds * block_size
    # global stream index of (d, r, b) = ((r * D) + d) * B + b
    d_ids = jnp.arange(num_devices, dtype=jnp.int32)[:, None, None]
    r_ids = jnp.arange(num_rounds, dtype=jnp.int32)[None, :, None]
    b_ids = jnp.arange(block_size, dtype=jnp.int32)[None, None, :]
    ib = (r_ids * num_devices + d_ids) * block_size + b_ids

    run = _compiled_dispersed(
        mesh, axis_name, num_devices, n, num_edges_padded, vector_rounds,
        tile_size, drain_rounds, faults, spec,
    )
    state, mask_padded, stats = run(ub, vb, ib)

    # map padded-stream mask back to the original edge order:
    # stream position of original edge k is k (dispersed_blocks keeps stream
    # order: block index = k // B, position = k % B)
    mask = mask_padded[:m]
    return _finalize(mask, state, stats)
