"""Multi-device Skipper via shard_map — devices play the paper's threads.

Protocol per round (DESIGN.md §2 level 1; paper Alg. 1 adapted to SPMD):

  1. LOCAL PASS — each device greedily matches its next dispersed edge block
     (plus its retry buffer) against its replica of the vertex-state array,
     exactly like a paper thread scanning its blocks. Local commits are
     *proposals* — the analogue of holding RSVD on both endpoints.
  2. GATHER — one all_gather moves the per-device proposal blocks (tiny:
     O(block) ints, no topology) to every device.
  3. REPLAY — every device applies the gathered proposals in the same
     deterministic position-major order with the same first-claim tile pass.
     Winners become MCHD everywhere (the committed state stays replicated-
     consistent); a proposal loses only if an endpoint was taken by an
     earlier-priority winner — i.e. the edge is *dead by MCHD endpoint*,
     Skipper's invariant.
  4. REQUEUE — edges the local pass killed via a *provisional* claim whose
     claimant then lost, and are still free post-replay, enter the retry
     buffer for the next round (the analogue of spinning on RSVD). Θ(λ²)-rare.

Each edge is decided exactly once except the rare requeues: total expected
work O(|E|/D + conflicts) per device, O(|E| + conflicts) aggregate — the
paper's single-pass property at block granularity.

Cross-pod: the all_gather composes over ("pod", "data") axes; proposal bytes
per round are independent of |E| (the paper's "conflict resolution touches no
topology").

Output is deterministic given (D, block_size) — see DESIGN.md assumption log.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.types import ACC, MCHD, STATE_DTYPE, Counters, MatchResult
from repro.core.engine import tile_pass
from repro.graphs.types import EdgeList
from repro.graphs.partition import dispersed_blocks


@dataclasses.dataclass(frozen=True)
class DistStats:
    """Per-run distributed accounting (aggregated over devices)."""

    proposals: jax.Array        # total proposals sent
    lost_proposals: jax.Array   # proposals that lost replay (cross-device JIT conflicts)
    requeued: jax.Array         # edges requeued (spin-wait analogue)
    retry_overflow: jax.Array   # edges dropped by a full retry buffer (must be 0)
    undrained: jax.Array        # retry entries alive after drain rounds (must be 0)
    gathered_ints: jax.Array    # collective payload (int32 count) over the run


def _local_pass(state, u, v, *, n, vector_rounds, tile_size):
    """Greedy pass of a [L]-sized slab in tiles. Returns (post local state,
    matched mask)."""
    l = u.shape[0]
    num_tiles = l // tile_size
    ut = u.reshape(num_tiles, tile_size)
    vt = v.reshape(num_tiles, tile_size)

    def step(st, uv):
        uu, vv = uv
        st, matched, _, _ = tile_pass(st, uu, vv, n=n, vector_rounds=vector_rounds)
        return st, matched

    state, matched = jax.lax.scan(step, state, (ut, vt))
    return state, matched.reshape(-1)


def _replay(state, u, v, *, n, vector_rounds, tile_size):
    """Deterministic first-claim replay of the gathered proposal stream."""
    return _local_pass(state, u, v, n=n, vector_rounds=vector_rounds, tile_size=tile_size)


def distributed_skipper_fn(
    u_blocks: jax.Array,   # [R, B] this device's dispersed blocks
    v_blocks: jax.Array,
    i_blocks: jax.Array,   # [R, B] global stream indices
    *,
    num_vertices: int,
    num_edges_padded: int,
    axis_name: str,
    num_devices: int,
    vector_rounds: int,
    tile_size: int,
    drain_rounds: int,
) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, ...]]:
    """Body executed per device under shard_map."""
    n = num_vertices
    # shard_map delivers the device-sharded leading axis as size 1: squeeze.
    u_blocks = u_blocks.reshape(u_blocks.shape[-2:])
    v_blocks = v_blocks.reshape(v_blocks.shape[-2:])
    i_blocks = i_blocks.reshape(i_blocks.shape[-2:])
    rounds, block = u_blocks.shape
    cap = block  # retry buffer capacity

    slab = block + cap  # edges examined per round
    # pad slab to tile multiple
    slab_pad = (-slab) % tile_size
    slab_t = slab + slab_pad

    def one_round(carry, blk):
        state, mask, ru, rv, ri, rcount, stats = carry
        bu, bv, bi = blk

        # 1. LOCAL PASS on [retry ++ block]
        u = jnp.concatenate([ru, bu, jnp.full((slab_pad,), -1, jnp.int32)])
        v = jnp.concatenate([rv, bv, jnp.full((slab_pad,), -1, jnp.int32)])
        idx = jnp.concatenate([ri, bi, jnp.full((slab_pad,), -1, jnp.int32)])
        local_state, proposed = _local_pass(
            state, u, v, n=n, vector_rounds=vector_rounds, tile_size=tile_size
        )
        valid = (u >= 0) & (u != v)
        # dead w.r.t. the committed (pre-round) state — permanent
        sgu = state[jnp.clip(u, 0, n - 1)]
        sgv = state[jnp.clip(v, 0, n - 1)]
        dead_global = valid & (~proposed) & ((sgu == MCHD) | (sgv == MCHD))
        dead_prov = valid & (~proposed) & (~dead_global)

        # 2. GATHER proposals (u,v,idx; -1 where not proposed)
        pu = jnp.where(proposed, u, -1)
        pv = jnp.where(proposed, v, -1)
        pi = jnp.where(proposed, idx, -1)
        gu = jax.lax.all_gather(pu, axis_name)  # [D, slab_t]
        gv = jax.lax.all_gather(pv, axis_name)
        gi = jax.lax.all_gather(pi, axis_name)
        # position-major (round-robin across devices) deterministic order
        gu = gu.T.reshape(-1)
        gv = gv.T.reshape(-1)
        gi = gi.T.reshape(-1)

        # 3. REPLAY on the committed state
        new_state, winners = _replay(
            state, gu, gv, n=n, vector_rounds=vector_rounds, tile_size=tile_size
        )
        mask = mask.at[jnp.where(winners, gi, num_edges_padded)].set(
            True, mode="drop"
        )

        # 4. REQUEUE provisional-dead edges that are still free post-replay
        snu = new_state[jnp.clip(u, 0, n - 1)]
        snv = new_state[jnp.clip(v, 0, n - 1)]
        requeue = dead_prov & (snu == ACC) & (snv == ACC)
        # compact requeued edges to the front of the retry buffer
        order = jnp.argsort(~requeue)  # True (=0 after ~) first
        ru_n = jnp.where(requeue[order], u[order], -1)[:cap]
        rv_n = jnp.where(requeue[order], v[order], -1)[:cap]
        ri_n = jnp.where(requeue[order], idx[order], -1)[:cap]
        nreq = jnp.sum(requeue)
        overflow = jnp.maximum(nreq - cap, 0)

        n_props = jnp.sum(proposed)
        # stats: proposals, lost, requeued, overflow, undrained, gathered ints
        props, lost, req, ovf, und, gints = stats
        stats = (
            props + n_props,
            lost,  # derived as (proposals - matches) at the host level
            req + nreq,
            ovf + overflow,
            und,
            gints + 3 * slab_t * num_devices,
        )
        return (new_state, mask, ru_n, rv_n, ri_n, rcount, stats), jnp.sum(winners)

    state0 = jnp.full((n,), ACC, STATE_DTYPE)
    mask0 = jnp.zeros((num_edges_padded,), jnp.bool_)
    empty = jnp.full((cap,), -1, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    stats0 = (z, z, z, z, z, z)
    carry0 = (state0, mask0, empty, empty, empty, z, stats0)

    carry, _ = jax.lax.scan(one_round, carry0, (u_blocks, v_blocks, i_blocks))

    # drain: extra rounds with empty blocks until retry buffers settle
    empty_blk = (
        jnp.full((drain_rounds, block), -1, jnp.int32),
        jnp.full((drain_rounds, block), -1, jnp.int32),
        jnp.full((drain_rounds, block), -1, jnp.int32),
    )
    carry, _ = jax.lax.scan(one_round, carry, empty_blk)

    state, mask, ru, rv, ri, _, stats = carry
    props, lost, req, ovf, und, gints = stats
    und = und + jnp.sum(ru >= 0)

    # aggregate stats over devices
    agg = lambda x: jax.lax.psum(x, axis_name)
    stats_out = (
        agg(props),
        lost,  # computed at host level (global winners vs proposals)
        agg(req),
        agg(ovf),
        agg(und),
        gints,  # identical on every device already
    )
    return state, mask, stats_out


def distributed_skipper(
    edges: EdgeList,
    mesh: Optional[Mesh] = None,
    axis_name: str = "data",
    block_size: int = 512,
    vector_rounds: int = 2,
    tile_size: int = 256,
    drain_rounds: int = 4,
) -> Tuple[MatchResult, DistStats]:
    """Run Skipper across the devices of ``mesh`` along ``axis_name``.

    Works for any device count >= 1 (D=1 degenerates to the single-device
    tiled matcher plus a no-op replay).
    """
    if mesh is None:
        devs = jax.devices()
        mesh = compat.make_mesh((len(devs),), (axis_name,))
    if isinstance(mesh.shape, dict):
        num_devices = mesh.shape[axis_name]
    else:  # pragma: no cover
        num_devices = dict(zip(mesh.axis_names, mesh.shape))[axis_name]

    n = edges.num_vertices
    m = edges.num_edges
    e = edges.canonical()
    ub, vb = dispersed_blocks(e, num_devices, block_size)  # [D, R, B]
    num_rounds = ub.shape[1]
    num_edges_padded = num_devices * num_rounds * block_size
    # global stream index of (d, r, b) = ((r * D) + d) * B + b
    d_ids = jnp.arange(num_devices, dtype=jnp.int32)[:, None, None]
    r_ids = jnp.arange(num_rounds, dtype=jnp.int32)[None, :, None]
    b_ids = jnp.arange(block_size, dtype=jnp.int32)[None, None, :]
    ib = (r_ids * num_devices + d_ids) * block_size + b_ids

    fn = partial(
        distributed_skipper_fn,
        num_vertices=n,
        num_edges_padded=num_edges_padded,
        axis_name=axis_name,
        num_devices=num_devices,
        vector_rounds=vector_rounds,
        tile_size=tile_size,
        drain_rounds=drain_rounds,
    )
    shard = compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(None), P(None), (P(),) * 6),
        check_vma=False,
    )
    state, mask_padded, stats = jax.jit(shard)(ub, vb, ib)

    # map padded-stream mask back to the original edge order:
    # stream position of original edge k is k (dispersed_blocks keeps stream
    # order: block index = k // B, position = k % B)
    mask = mask_padded[:m]
    props, _, req, ovf, und, gints = stats
    n_match = jnp.sum(mask)
    lost = props - n_match  # proposals that did not win the replay
    counters = Counters(
        edge_reads=jnp.asarray(m, jnp.int32),
        state_loads=jnp.asarray(2 * m, jnp.int32) + 2 * req,
        state_stores=2 * n_match.astype(jnp.int32),
        rounds=jnp.asarray(1, jnp.int32),
    )
    result = MatchResult(match_mask=mask, state=state, counters=counters)
    dstats = DistStats(
        proposals=props,
        lost_proposals=lost,
        requeued=req,
        retry_overflow=ovf,
        undrained=und,
        gathered_ints=gints,
    )
    return result, dstats
