"""Core: the paper's contribution — single-pass maximal matching with JIT
conflict resolution — plus the baselines it is evaluated against.
"""
from repro.core.types import ACC, RSVD, MCHD, Counters, MatchResult
from repro.core.sgmm import sgmm
from repro.core.skipper import skipper
from repro.core.ems import ems_israeli_itai, ems_idmm, sidmm
from repro.core.faults import (
    FaultPlan,
    RecoveryReport,
    detect_residual,
    residual_replay,
)
from repro.core.validate import check_matching, assert_matching
from repro.core.bipartite import bmatch_assign
from repro.core.conflicts import conflict_table

__all__ = [
    "ACC",
    "RSVD",
    "MCHD",
    "Counters",
    "MatchResult",
    "sgmm",
    "skipper",
    "ems_israeli_itai",
    "ems_idmm",
    "sidmm",
    "FaultPlan",
    "RecoveryReport",
    "detect_residual",
    "residual_replay",
    "check_matching",
    "assert_matching",
    "bmatch_assign",
    "conflict_table",
]
