"""Fault injection + graceful-degradation recovery (DESIGN.md §11).

Skipper's central guarantee — every edge is processed once and *definitively*
decided — is exactly what a distributed port can silently lose: a full retry
buffer or an undrained cross-window queue drops edges, a lost shard drops a
whole window's decisions, a corrupted state byte turns live vertices into
zombies no edge can match. This module provides both halves of the failure
story:

**Injection** (:class:`FaultPlan`): a seeded, deterministic description of
which failure sites fire and at what rate. The plan is a frozen (hashable)
dataclass so it rides the compiled-function caches as a static argument;
every injection is gated at trace time (``plan is None`` — the default —
adds literally zero ops to the compiled graph, test- and bench-pinned).
Sites, and where each one is wired in:

* ``drop_proposals`` — Bernoulli-drop proposal slots *before* the gather
  (``distributed._make_round_fn``); the local device believes it proposed,
  so the edge is never requeued: the silent-loss failure mode. In the
  single-device pipeline the same mask invalidates global-tier slots before
  the epilogue (``kernels/skipper_match/ops``) — same victims at D=1.
* ``truncate_retry`` — force the retry-buffer capacity down to ``k`` slots
  so requeues overflow (``retry_overflow`` trips).
* ``corrupt_state`` — Bernoulli-set committed-state bytes to the
  out-of-domain :data:`CORRUPT` value. Out-of-domain corruption can only
  *kill* edges (a corrupted cell is neither ACC nor MCHD, so no edge on it
  is ever free), i.e. it breaks maximality but never validity — which is
  what makes mask-anchored recovery (below) sound.
* ``lose_shard`` — zero one device's window-tier contribution (state rows
  AND matched bits together, so the loss is internally consistent) and
  swallow its global-tier proposals; in the single-device pipeline the
  analogue loses one window row.
* ``skip_drain`` — force the drain rounds to zero so live retry entries
  survive the run (``undrained`` trips).

**Recovery** (:func:`residual_replay`): the provably-completing final rung
of ``on_fault="recover"``'s ladder. The returned ``match_mask`` is the
ground truth (every fault above preserves its validity); the committed
state is NOT trusted (it may be corrupted or partially lost). So: rebuild
the vertex state purely from the mask, collect the *residual* edges —
valid, unmatched, neither endpoint covered — and run the standard
first-claim tile rounds (``engine.stream_pass``, the exact same engine
every matcher uses) over them in stream order. After the pass no valid
edge is free, hence the result is maximal; commits are endpoint-disjoint
by the engine invariant, hence it stays valid. Out-of-domain bytes are
detected on the returned state (``corrupted_cells``) and simply vanish in
the rebuild — their vertices' edges are re-decided in the same pass.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import ACC, MCHD, stream_pass
from repro.core.statespec import StateSpec, resolve as resolve_spec
from repro.graphs.types import EdgeList

__all__ = [
    "CORRUPT",
    "FaultPlan",
    "RecoveryReport",
    "corruption_mask",
    "proposal_drop_mask",
    "detect_residual",
    "residual_replay",
]

# Out-of-domain state byte injected by ``corrupt_state`` — anything outside
# {ACC=0, RSVD=1, MCHD=2} works; 7 is visibly wrong in dumps.
CORRUPT = 7

# Site keys folded into the plan's PRNG key so every site draws an
# independent, reproducible stream (shared by the traced injection code and
# the host-side test oracles re-deriving the victim sets).
_SITE_DROP = 1
_SITE_CORRUPT = 2


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, deterministic fault-injection plan (all sites default off).

    Hashable and fully static: it participates in the compiled-function
    cache keys, and two runs with the same plan + schedule inject the exact
    same faults (the chaos tests and the host-side victim oracles rely on
    this).
    """

    seed: int = 0
    drop_proposals: float = 0.0          # P(drop) per global-tier stream slot
    truncate_retry: Optional[int] = None  # retry cap forced to min(cap, k)
    corrupt_state: float = 0.0           # P(corrupt) per committed-state cell
    lose_shard: Optional[int] = None     # device (mod D) whose window tier is lost
    skip_drain: bool = False             # drain rounds forced to 0

    @property
    def active(self) -> bool:
        return (
            self.drop_proposals > 0.0
            or self.truncate_retry is not None
            or self.corrupt_state > 0.0
            or self.lose_shard is not None
            or self.skip_drain
        )


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What the degradation machinery saw and did (all zero on a clean run).

    ``recovery_attempts`` counts ladder steps that actually did something:
    in-protocol escalation re-runs plus the residual replay if it recovered
    anything. ``residual_edges`` is the number of valid edges left undecided
    (unmatched with both endpoints uncovered) before the replay;
    ``recovered_matches`` how many matches the replay added;
    ``corrupted_cells`` how many out-of-domain state bytes were detected on
    the returned state (they are cleaned by the replay's rebuilt state).
    """

    recovery_attempts: int = 0
    residual_edges: int = 0
    recovered_matches: int = 0
    corrupted_cells: int = 0


def proposal_drop_mask(plan: FaultPlan, num_slots: int) -> jax.Array:
    """bool[num_slots] — True where the plan drops a global-tier stream slot.

    Keyed only by ``(plan.seed, num_slots)``, so the distributed gather-drop
    and the single-device epilogue-drop pick the SAME victims for the same
    schedule, and tests re-derive the victim set host-side."""
    key = jax.random.fold_in(jax.random.PRNGKey(plan.seed), _SITE_DROP)
    return jax.random.bernoulli(key, plan.drop_proposals, (num_slots,))


def corruption_mask(plan: FaultPlan, num_cells: int) -> jax.Array:
    """bool[num_cells] — True where the plan corrupts a committed-state cell
    (cells are in the state's own id space: renumbered-flat for the windowed
    pipelines, original vertex ids for the dispersed path)."""
    key = jax.random.fold_in(jax.random.PRNGKey(plan.seed), _SITE_CORRUPT)
    return jax.random.bernoulli(key, plan.corrupt_state, (num_cells,))


def _rebuild_and_residual(e: EdgeList, match_mask, state,
                          spec: Optional[StateSpec] = None):
    """Shared detection core: mask-rebuilt state, residual-edge mask, and
    the out-of-domain cell count of the (untrusted) returned ``state``.
    The rebuild is allocated at the spec's at-rest width (the incoming
    ``state`` is inspected dtype-agnostically — plain-int compares — so
    detection works at any width)."""
    spec = resolve_spec(spec)
    n = e.num_vertices
    valid = (e.u != e.v) & (e.u >= 0) & (e.v < n)
    sel = match_mask & valid
    rebuilt = jnp.full((n + 1,), ACC, spec.at_rest_dtype)
    rebuilt = rebuilt.at[jnp.where(sel, e.u, n)].set(MCHD, mode="drop")
    rebuilt = rebuilt.at[jnp.where(sel, e.v, n)].set(MCHD, mode="drop")
    # index n = guard slot (ACC) so invalid edges never read a real vertex
    su = rebuilt[jnp.where(valid, e.u, n)]
    sv = rebuilt[jnp.where(valid, e.v, n)]
    residual = valid & (~match_mask) & (su != MCHD) & (sv != MCHD)
    corrupted = jnp.sum(
        (state != ACC) & (state != MCHD), dtype=jnp.int32
    )
    return rebuilt[:n], residual, corrupted


@jax.jit
def _detect(e: EdgeList, match_mask, state):
    _, residual, corrupted = _rebuild_and_residual(e, match_mask, state)
    return jnp.sum(residual, dtype=jnp.int32), corrupted


def detect_residual(
    edges: EdgeList, match_mask: jax.Array, state: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(residual_edges, corrupted_cells) of a finished run — the detection
    half of the ladder, used by ``on_fault="report"`` and ``verify=``.
    Zero/zero iff the run upheld the definitive-decision invariant."""
    return _detect(edges.canonical(), match_mask, state)


@partial(jax.jit, static_argnames=("tile_size", "vector_rounds", "spec"))
def _replay(e: EdgeList, match_mask, state, tile_size: int, vector_rounds: int,
            spec: Optional[StateSpec] = None):
    spec = resolve_spec(spec)
    n = e.num_vertices
    m = e.num_edges
    rebuilt, residual, corrupted = _rebuild_and_residual(
        e, match_mask, state, spec
    )
    # feed ONLY the residual edges to the engine (others masked invalid),
    # padded to a tile multiple, in stream order — the replay is literally
    # one more single pass over the (residual) edges.
    pad = (-m) % tile_size
    ru = jnp.concatenate(
        [jnp.where(residual, e.u, -1), jnp.full((pad,), -1, jnp.int32)]
    )
    rv = jnp.concatenate(
        [jnp.where(residual, e.v, -1), jnp.full((pad,), -1, jnp.int32)]
    )
    final_state, matched, _ = stream_pass(
        rebuilt, ru, rv, n=n, vector_rounds=vector_rounds, tile_size=tile_size
    )
    mask_out = match_mask | (matched[:m] > 0)
    return (
        mask_out,
        final_state,
        jnp.sum(residual, dtype=jnp.int32),
        jnp.sum(matched[:m], dtype=jnp.int32).astype(jnp.int32),
        corrupted,
    )


def residual_replay(
    edges: EdgeList,
    match_mask: jax.Array,
    state: jax.Array,
    *,
    tile_size: int = 256,
    vector_rounds: int = 1,
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """The recovery ladder's final rung: complete a (possibly degraded)
    matching into a valid+maximal one on the uncorrupted graph.

    Anchors on ``match_mask`` (kept verbatim — every modeled fault preserves
    its validity), rebuilds the vertex state from it, and runs the engine's
    first-claim rounds over the residual edges in stream order. Returns
    ``(match_mask, state, residual_edges, recovered_matches,
    corrupted_cells)`` where the returned state is the *clean* rebuilt one
    (corruption does not survive). ``residual_edges == 0`` and
    ``corrupted_cells == 0`` means the input was already maximal and clean,
    and the mask comes back unchanged. ``spec`` sets the rebuilt state's
    at-rest width (the replay itself is width-polymorphic).
    """
    return _replay(
        edges.canonical(), match_mask, state, tile_size, vector_rounds,
        resolve_spec(spec),
    )
