"""Matching output validation (paper §II-B):

  (a) validity  — no two selected edges share an endpoint;
  (b) maximality — every (non-self, non-duplicate-dead) edge shares an
      endpoint with a selected edge.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.graphs.types import EdgeList


@jax.jit
def check_matching(edges: EdgeList, match_mask: jax.Array) -> Dict[str, jax.Array]:
    e = edges.canonical()
    n = e.num_vertices
    valid = (e.u != e.v) & (e.u >= 0)
    mask = match_mask & valid

    inc = jnp.zeros((n + 1,), jnp.int32)
    inc = inc.at[jnp.where(mask, e.u, n)].add(1, mode="drop")
    inc = inc.at[jnp.where(mask, e.v, n)].add(1, mode="drop")
    inc = inc[:n]
    is_valid = jnp.all(inc <= 1)

    covered = inc > 0
    cov_u = covered[jnp.where(valid, e.u, 0)]
    cov_v = covered[jnp.where(valid, e.v, 0)]
    is_maximal = jnp.all(~valid | cov_u | cov_v)

    return {
        "valid": is_valid,
        "maximal": is_maximal,
        "num_matches": jnp.sum(mask),
        "num_covered_vertices": jnp.sum(covered),
    }


def assert_matching(edges: EdgeList, match_mask: jax.Array, label: str = "") -> Dict[str, int]:
    out = {k: v.item() if hasattr(v, "item") else v for k, v in check_matching(edges, match_mask).items()}
    assert out["valid"], f"{label}: matching has endpoint collisions"
    assert out["maximal"], f"{label}: matching is not maximal"
    return out
