"""Matching output validation (paper §II-B):

  (a) validity  — no two selected edges share an endpoint;
  (b) maximality — every (non-self, non-duplicate-dead) edge shares an
      endpoint with a selected edge.

Consumed directly by tests and — since the failure-model PR — by the
matchers themselves behind ``verify=`` (``skipper_match`` /
``distributed_skipper`` / ``skipper``), so the degenerate and
out-of-range cases below are load-bearing, not defensive.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.graphs.types import EdgeList


@jax.jit
def check_matching(edges: EdgeList, match_mask: jax.Array) -> Dict[str, jax.Array]:
    e = edges.canonical()
    n = e.num_vertices
    if e.num_edges == 0 or n == 0:
        # Degenerate inputs: nothing to select and nothing left uncovered —
        # vacuously a valid maximal matching. Returned explicitly because
        # zero-size scatters / jnp.all over empty axes are exactly the edge
        # cases jit'd reductions get wrong subtly (shape [0] all() is True,
        # but the [n+1] scatter below would still build inc of shape [1]
        # from n==0 and gather it for every dead edge).
        false_count = jnp.zeros((), jnp.int32)
        return {
            "valid": jnp.asarray(True),
            "maximal": jnp.asarray(True),
            "num_matches": false_count,
            "num_covered_vertices": false_count,
        }
    # out-of-range guard: canonical() gives u <= v, so v < n bounds both
    # endpoints — rows pointing past num_vertices are dead, never aliased
    # onto a real vertex.
    valid = (e.u != e.v) & (e.u >= 0) & (e.v < n)
    mask = match_mask & valid

    inc = jnp.zeros((n + 1,), jnp.int32)
    inc = inc.at[jnp.where(mask, e.u, n)].add(1, mode="drop")
    inc = inc.at[jnp.where(mask, e.v, n)].add(1, mode="drop")
    is_valid = jnp.all(inc[:n] <= 1)

    # slot n is always uncovered: dead edges gather it instead of aliasing
    # vertex 0 (whose coverage would vacuously "satisfy" them)
    covered = jnp.concatenate([inc[:n] > 0, jnp.zeros((1,), jnp.bool_)])
    cov_u = covered[jnp.where(valid, e.u, n)]
    cov_v = covered[jnp.where(valid, e.v, n)]
    is_maximal = jnp.all(~valid | cov_u | cov_v)

    return {
        "valid": is_valid,
        "maximal": is_maximal,
        "num_matches": jnp.sum(mask),
        "num_covered_vertices": jnp.sum(covered[:n]),
    }


@jax.jit
def check_state_domain(state: jax.Array) -> Dict[str, jax.Array]:
    """Domain check of a final vertex-state array, at ANY state width.

    A finished run's state holds only ACC(0) or MCHD(2) — RSVD never
    survives a tile, and anything else is corruption (``faults.CORRUPT``
    lands here). Comparisons are against plain ints, so uint8 and int32
    state (any ``core/statespec.StateSpec`` width) validate identically —
    which is exactly what the spec-equivalence tests need to pin.

    Returns ``{"clean": bool, "out_of_domain": int32, "rsvd_leaked":
    int32}`` — ``clean`` iff both counts are zero.
    """
    ood = jnp.sum((state != 0) & (state != 1) & (state != 2),
                  dtype=jnp.int32)
    rsvd = jnp.sum(state == 1, dtype=jnp.int32)
    return {
        "clean": (ood == 0) & (rsvd == 0),
        "out_of_domain": ood,
        "rsvd_leaked": rsvd,
    }


def _first_offender(edges: EdgeList, match_mask) -> str:
    """Host-side diagnosis for a failed check: the FIRST stream edge that
    breaks validity (a selected edge hitting an endpoint an earlier
    selected edge already covered) or, failing that, maximality (a valid
    edge left unmatched with both endpoints uncovered). Runs only on the
    failure path — plain numpy, synchronizes."""
    import numpy as np

    e = edges.canonical()
    u = np.asarray(e.u, np.int64)
    v = np.asarray(e.v, np.int64)
    n = e.num_vertices
    mask = np.asarray(match_mask, bool)
    valid = (u != v) & (u >= 0) & (v < n)
    covered = np.zeros(n, bool)
    for i in np.flatnonzero(mask & valid):
        if covered[u[i]] or covered[v[i]]:
            return (f"first offending edge ({u[i]}, {v[i]}) at stream "
                    f"index {i}: selected but an endpoint is already "
                    "covered by an earlier selected edge")
        covered[u[i]] = covered[v[i]] = True
    free = valid & ~mask & ~covered[np.clip(u, 0, n - 1)] \
        & ~covered[np.clip(v, 0, n - 1)]
    if free.any():
        i = int(np.flatnonzero(free)[0])
        return (f"first offending edge ({u[i]}, {v[i]}) at stream index "
                f"{i}: unmatched with both endpoints uncovered")
    return "no offending edge found (mask/graph disagree with the check?)"


def assert_matching(edges: EdgeList, match_mask: jax.Array, label: str = "") -> Dict[str, int]:
    out = {k: v.item() if hasattr(v, "item") else v  # host-sync: ok (assert helper)
           for k, v in check_matching(edges, match_mask).items()}
    assert out["valid"], (
        f"{label}: matching has endpoint collisions — "
        + _first_offender(edges, match_mask)
    )
    assert out["maximal"], (
        f"{label}: matching is not maximal — "
        + _first_offender(edges, match_mask)
    )
    return out
