"""Capacity-constrained bipartite b-matching — the Skipper technique applied
to MoE token-expert assignment (first-class framework integration, DESIGN §3).

Problem: tokens x experts, candidate edges (t, e) with router scores; each
token may take at most ``token_budget`` experts, each expert at most
``expert_capacity`` tokens. A maximal b-matching over the score-sorted edge
stream is the single-pass analogue of auction/Sinkhorn routing.

Algorithm = Skipper's tiled first-claim pass generalized to capacities:

  per tile (vectorized):
    expert side: prefix-count of same-expert claims inside the tile via a
        one-hot cumsum (experts are few, so the T x E one-hot is cheap — on
        TPU this is an MXU matmul);
    token side:  an edge is *clean* iff no earlier in-tile edge claims the
        same token (first-claim, same triangular mask as unipartite Skipper);
    commit = clean & token-budget-left & expert-capacity-left-after-prefix.
  Dirty edges (second+ in-tile claim on one token) retry in the next unrolled
  round — the JIT conflict path. Every edge is decided in its own tile.

Work: O(#candidate edges), one pass, no iteration over the token set — the
same work-efficiency story the paper tells for graphs.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _tile_round(
    tok: jax.Array,          # int32[T] token ids (already -1 for invalid)
    exp: jax.Array,          # int32[T] expert ids
    undecided: jax.Array,    # bool[T]
    token_used: jax.Array,   # int32[num_tokens]
    expert_used: jax.Array,  # int32[num_experts]
    token_budget: int,
    expert_capacity: int,
    num_experts: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    t = tok.shape[0]
    num_tokens = token_used.shape[0]
    valid = (tok >= 0) & undecided

    tok_left = token_used[jnp.where(valid, tok, 0)] < token_budget
    exp_left = expert_used[jnp.where(valid, exp, 0)] < expert_capacity
    # dead edges are decided now (token budget exhausted or expert full)
    dead = valid & (~tok_left | ~exp_left)
    free = valid & tok_left & exp_left

    # token first-claim (triangular conflict mask over the tile)
    same_tok = (tok[:, None] == tok[None, :]) & jnp.tril(
        jnp.ones((t, t), jnp.bool_), k=-1
    )
    blocked_tok = jnp.any(same_tok & free[None, :], axis=1) & free

    # expert prefix count inside the tile (one-hot cumsum; MXU-sized)
    onehot = jax.nn.one_hot(
        jnp.where(free & ~blocked_tok, exp, num_experts),
        num_experts + 1,
        dtype=jnp.int32,
    )[:, :num_experts]
    prefix = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix
    exp_prefix = jnp.sum(prefix * onehot, axis=1)
    exp_room = expert_used[jnp.where(valid, exp, 0)] + exp_prefix < expert_capacity

    commit = free & ~blocked_tok & exp_room
    over = free & ~blocked_tok & ~exp_room  # expert filled within this tile -> dead
    token_used = token_used.at[jnp.where(commit, tok, num_tokens)].add(1, mode="drop")
    expert_used = expert_used.at[jnp.where(commit, exp, num_experts)].add(1, mode="drop")
    undecided = undecided & ~(commit | dead | over)
    return commit, undecided, token_used, expert_used


@partial(
    jax.jit,
    static_argnames=(
        "num_tokens",
        "num_experts",
        "token_budget",
        "expert_capacity",
        "tile_size",
        "vector_rounds",
    ),
)
def bmatch_assign(
    token_ids: jax.Array,
    expert_ids: jax.Array,
    *,
    num_tokens: int,
    num_experts: int,
    token_budget: int,
    expert_capacity: int,
    tile_size: int = 1024,
    vector_rounds: int = 3,
) -> jax.Array:
    """Greedy maximal b-matching over a (pre-sorted) candidate edge stream.

    token_ids/expert_ids: int32[M] candidate edges, highest score first;
    invalid candidates marked token_id = -1. Returns bool[M] accept mask.
    """
    m = token_ids.shape[0]
    pad = (-m) % tile_size
    tok = jnp.concatenate([token_ids, jnp.full((pad,), -1, jnp.int32)])
    exp = jnp.concatenate([expert_ids, jnp.zeros((pad,), jnp.int32)])
    num_tiles = tok.shape[0] // tile_size
    tok = tok.reshape(num_tiles, tile_size)
    exp = exp.reshape(num_tiles, tile_size)

    def tile_step(carry, te):
        token_used, expert_used = carry
        t_ids, e_ids = te
        undecided = jnp.ones((tile_size,), jnp.bool_)
        matched = jnp.zeros((tile_size,), jnp.bool_)
        for _ in range(vector_rounds):
            commit, undecided, token_used, expert_used = _tile_round(
                t_ids, e_ids, undecided, token_used, expert_used,
                token_budget, expert_capacity, num_experts,
            )
            matched = matched | commit

        # sequential fallback for still-undecided edges (token appeared >
        # vector_rounds times in one tile)
        def fallback(args):
            token_used, expert_used, matched = args

            def fstep(c, te_u):
                tu, eu, mm_prev = c
                tt, ee, und = te_u
                ok = und & (tt >= 0)
                take = (
                    ok
                    & (tu[jnp.where(ok, tt, 0)] < token_budget)
                    & (eu[jnp.where(ok, ee, 0)] < expert_capacity)
                )
                tu = tu.at[jnp.where(take, tt, num_tokens)].add(1, mode="drop")
                eu = eu.at[jnp.where(take, ee, num_experts)].add(1, mode="drop")
                return (tu, eu, mm_prev), take

            (token_used, expert_used, _), extra = jax.lax.scan(
                fstep, (token_used, expert_used, matched), (t_ids, e_ids, undecided)
            )
            return token_used, expert_used, matched | extra

        token_used, expert_used, matched = jax.lax.cond(
            jnp.any(undecided),
            fallback,
            lambda args: args,
            (token_used, expert_used, matched),
        )
        return (token_used, expert_used), matched

    carry0 = (
        jnp.zeros((num_tokens,), jnp.int32),
        jnp.zeros((num_experts,), jnp.int32),
    )
    _, matched = jax.lax.scan(tile_step, carry0, (tok, exp))
    return matched.reshape(-1)[:m]
