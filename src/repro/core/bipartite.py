"""Capacity-constrained bipartite b-matching — the Skipper technique applied
to MoE token-expert assignment (first-class framework integration, DESIGN.md
§3, §9).

Problem: tokens x experts, candidate edges (t, e) with router scores; each
token may take at most ``token_budget`` experts, each expert at most
``expert_capacity`` tokens. A maximal b-matching over the score-sorted edge
stream is the single-pass analogue of auction/Sinkhorn routing.

Since PR 4 this module is a THIN ADAPTER over the shared claim engine: the
round/fallback machinery lives in ``core/engine.py`` (the capacitated
first-K-claim generalization — ``tile_pass_capacitated`` built on
``run_first_claim_rounds`` / ``greedy_fallback_rounds``), so the b-matching
inherits every engine speedup (per-side blocked implementations, future
Pallas tiling) for free, and its output is *exactly* the sequential greedy
over the score-sorted stream: accept each edge iff, at its stream position,
its token still has budget and its expert still has capacity (test-pinned
against a numpy oracle). The previous private implementation's one-commit-
per-token-per-round rule and vmap-degrading ``lax.cond`` + ``lax.scan``
fallback (the same pathology PR 2 removed from the engine) are gone.

Work: O(#candidate edges), one pass, no iteration over the token set — the
same work-efficiency story the paper tells for graphs.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.statespec import StateSpec, resolve as resolve_spec

# Default unrolled rounds per tile. NOT a correctness knob (the engine's
# exact fallback reaches the sequential-greedy fixpoint from any unroll
# depth — rounds-invariance is test-pinned), but unlike the unipartite
# matchers the capacitated default is 2, not 1: the score-sorted MoE stream
# is *structurally* contended — hot experts draw claimants until they fill,
# and a token's budget-k candidates land in the same tile — so round-2 work
# is common rather than Θ(λ²)-rare. Round 1 commits each vertex's first
# `room` claims; round 2 retires the cross-side chains that round 1's
# commits unblock (see DESIGN.md §9). With vector_rounds=1 those chains fall
# into the while_loop fallback, which under vmap (the MoE router vmaps
# groups) costs every group the batch-max iteration count;
# tests/test_bipartite.py::test_rounds_sensitivity pins both the invariance
# and the round-2 economics.
BMATCH_VECTOR_ROUNDS = 2


@partial(
    jax.jit,
    static_argnames=(
        "num_tokens",
        "num_experts",
        "token_budget",
        "expert_capacity",
        "tile_size",
        "vector_rounds",
        "conflict_method",
        "with_stats",
        "spec",
    ),
)
def bmatch_assign(
    token_ids: jax.Array,
    expert_ids: jax.Array,
    *,
    num_tokens: int,
    num_experts: int,
    token_budget: int,
    expert_capacity: int,
    tile_size: int = 1024,
    vector_rounds: int = BMATCH_VECTOR_ROUNDS,
    conflict_method: str = "auto",
    with_stats: bool = False,
    spec: Optional[StateSpec] = None,
) -> Union[jax.Array, Tuple[jax.Array, Dict[str, jax.Array]]]:
    """Greedy maximal b-matching over a (pre-sorted) candidate edge stream.

    token_ids/expert_ids: int32[M] candidate edges, highest score first;
    invalid candidates marked token_id = -1. Returns bool[M] accept mask —
    exactly the sequential greedy: edge i is accepted iff at stream position
    i its token has budget left and its expert has capacity left.

    The work is ``engine.tile_pass_capacitated`` scanned over
    ``tile_size``-edge tiles with the per-side used counts as carry
    (DESIGN.md §9); ``conflict_method`` is forwarded to the engine's
    per-side rank implementations (``"auto"`` picks the one-hot prefix for
    the expert side and claim-sort for the token side at typical sizes —
    never changes output).

    ``with_stats=True`` additionally returns
    ``{"conflicts": int32, "fallback_tiles": int32}`` — total blocked-round
    count (Table II analogue) and how many tiles entered the exact
    while_loop fallback (the rounds-sensitivity instrumentation).

    ``spec`` (``core/statespec.StateSpec``) sets the used-count width —
    used counts ARE this problem's vertex state, so the default spec keeps
    them at 1 B per token/expert whenever the static budgets fit the
    at-rest dtype (``validate_capacity``), falling back to the i32
    accumulator width otherwise; the engine widens to i32 at the gather
    either way. Stats accumulate in int32 regardless of the spec.
    """
    spec = resolve_spec(spec)
    fits = spec.validate_capacity(max(token_budget, expert_capacity))
    used_dt = spec.at_rest_dtype if fits else spec.accum_dtype
    m = token_ids.shape[0]
    pad = (-m) % tile_size
    tok = jnp.concatenate(
        [token_ids.astype(jnp.int32), jnp.full((pad,), -1, jnp.int32)]
    )
    exp = jnp.concatenate(
        [expert_ids.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)]
    )
    num_tiles = tok.shape[0] // tile_size
    tok = tok.reshape(num_tiles, tile_size)
    exp = exp.reshape(num_tiles, tile_size)

    def tile_step(carry, te):
        used_t, used_e = carry
        (used_t, used_e), matched, conflicts, fb = engine.tile_pass_capacitated(
            used_t, used_e, te[0], te[1],
            cap_u=token_budget, cap_v=expert_capacity,
            vector_rounds=vector_rounds, conflict_method=conflict_method,
        )
        return (used_t, used_e), (matched, conflicts, fb)

    carry0 = (
        jnp.zeros((num_tokens,), used_dt),
        jnp.zeros((num_experts,), used_dt),
    )
    _, (matched, conflicts, fb) = jax.lax.scan(tile_step, carry0, (tok, exp))
    accept = matched.reshape(-1)[:m]
    if with_stats:
        # conflicts come back i32 from the engine (no spec forwarded — they
        # are summed here and must not wrap at a narrow width)
        stats = {
            "conflicts": jnp.sum(conflicts.astype(jnp.int32)),
            "fallback_tiles": jnp.sum(fb.astype(jnp.int32)),
        }
        return accept, stats
    return accept
