"""StateSpec — the single source of truth for vertex-state width.

Skipper's memory claim is "a single byte per vertex". Historically this
repro honored that only *at rest* (``types.STATE_DTYPE = uint8``): the
Pallas VMEM window state, the aliased ANY-memory state in the block-pair
boundary epilogue, the distributed O(V) state assembly, and the per-edge
matched/conflict outputs were all ``int32`` — 4x the paper's footprint in
every hot tier and 4x the collective payload.

``StateSpec`` names one dtype per tier and every layer takes the spec
instead of hardcoding a width:

====================  =====================================================
field                 governs
====================  =====================================================
``at_rest``           HBM / returned vertex-state arrays (``MatchResult``,
                      residual-replay rebuilds, ``skipper()`` init state)
``vmem``              kernel-tier working state: Pallas VMEM window blocks,
                      the boundary kernel's ANY-memory state + (2, W) pair
                      scratch, and the XLA twin's scan carry
``wire``              distributed state-assembly payload (the O(V)
                      cross-device combine in the sharded matcher)
``counter``           per-edge matched/conflicts output arrays (the O(E)
                      buffers written by the kernels and the twin)
``accum``             index math and one-hot/matmul accumulators — always
                      ``int32``; the MXU gathers widen state to this dtype
                      *inside* the kernel (``hu @ state`` promotes u8 to
                      i32) and narrow back only at the scatter
``combine``           state-assembly combine policy: ``"max"`` (width
                      honest — rows are device-disjoint so ``pmax`` is
                      exact at any width and cannot overflow) or
                      ``"psum"`` (the legacy i32 graph)
====================  =====================================================

Two blessed specs:

* ``StateSpec.u8()`` (the module ``DEFAULT``) — single-byte state in every
  tier; bit-identical matchings to legacy (pinned by
  ``tests/test_statespec.py``'s equivalence matrix).
* ``StateSpec.legacy_i32()`` — compiles the exact pre-refactor Pallas
  graph (i32 VMEM state, i32 counters, psum assembly) for A/B benching.

The spec is a frozen dataclass holding dtype *names* (strings), so it is
hashable and participates directly in every ``lru_cache`` key and jit
static argument along the build path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_DTYPES = {"uint8": jnp.uint8, "int32": jnp.int32}
_DTYPE_BYTES = {"uint8": 1, "int32": 4}
_DTYPE_MAX = {"uint8": 255, "int32": 2**31 - 1}
_COMBINES = ("max", "psum")


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Per-tier vertex-state widths (see module docstring for the table)."""

    at_rest: str = "uint8"
    vmem: str = "uint8"
    wire: str = "uint8"
    counter: str = "uint8"
    accum: str = "int32"
    combine: str = "max"

    def __post_init__(self):
        for field in ("at_rest", "vmem", "wire", "counter", "accum"):
            name = getattr(self, field)
            if name not in _DTYPES:
                raise ValueError(
                    f"StateSpec.{field}={name!r}: must be one of "
                    f"{sorted(_DTYPES)}")
        if self.combine not in _COMBINES:
            raise ValueError(
                f"StateSpec.combine={self.combine!r}: must be one of "
                f"{_COMBINES}")
        if self.accum != "int32":
            # index math / one-hot accumulators are what the MXU and the
            # scatter adds run in; nothing narrower is sound for V > 255
            raise ValueError("StateSpec.accum must be 'int32'")

    # --- dtypes ----------------------------------------------------------
    @property
    def at_rest_dtype(self):
        return _DTYPES[self.at_rest]

    @property
    def vmem_dtype(self):
        return _DTYPES[self.vmem]

    @property
    def wire_dtype(self):
        return _DTYPES[self.wire]

    @property
    def counter_dtype(self):
        return _DTYPES[self.counter]

    @property
    def accum_dtype(self):
        return _DTYPES[self.accum]

    # --- widths ----------------------------------------------------------
    @property
    def at_rest_bytes(self) -> int:
        return _DTYPE_BYTES[self.at_rest]

    @property
    def vmem_bytes(self) -> int:
        return _DTYPE_BYTES[self.vmem]

    @property
    def wire_bytes(self) -> int:
        return _DTYPE_BYTES[self.wire]

    @property
    def counter_bytes(self) -> int:
        return _DTYPE_BYTES[self.counter]

    # --- guards ----------------------------------------------------------
    def validate_rounds(self, vector_rounds: int) -> None:
        """Raise if the narrowed conflict counter cannot hold the bound.

        A conflict counter increments at most once per first-claim round,
        so ``conflicts <= vector_rounds`` and narrowing the O(E) conflicts
        output to ``counter`` is exact iff ``vector_rounds`` fits. (The
        fallback tier reports a boolean flag, not a count, so it never
        exceeds the bound.) Called by every kernel builder at build time.
        """
        if vector_rounds > _DTYPE_MAX[self.counter]:
            raise ValueError(
                f"vector_rounds={vector_rounds} overflows the "
                f"{self.counter} conflict counter (max "
                f"{_DTYPE_MAX[self.counter]}); use a wider "
                f"StateSpec.counter")

    def validate_capacity(self, cap: int) -> bool:
        """True iff a used-count bounded by ``cap`` fits ``at_rest``.

        The capacitated engine's used-counts are per-vertex state; they
        never exceed the static capacity, so the narrow width is exact iff
        the capacity itself fits. Callers fall back to ``accum`` when not.
        """
        return cap <= _DTYPE_MAX[self.at_rest]

    # --- distributed combine --------------------------------------------
    def combine_rows(self, rows, axis_name):
        """Width-honest cross-device combine of the O(V) state assembly.

        Each (row, slot) cell is written by exactly one device (the row
        owner) and is zero (ACC) everywhere else, so the per-cell combine
        over disjoint contributions is exact under ``max`` at ANY width:
        a real value v > 0 beats the zeros, and ties (all-zero) stay zero.
        ``psum`` is equally exact on disjoint rows but only at widths
        where ``num_devices * max_state_value`` cannot wrap — which is why
        the legacy i32 graph could use it and a u8 wire cannot.
        """
        if self.combine == "psum":
            return jax.lax.psum(rows, axis_name)
        return jax.lax.pmax(rows, axis_name)

    # --- blessed specs ---------------------------------------------------
    @classmethod
    def u8(cls) -> "StateSpec":
        """Single-byte state in every tier (the default)."""
        return cls()

    @classmethod
    def legacy_i32(cls) -> "StateSpec":
        """The exact pre-refactor graph: i32 kernel/wire state, i32
        counters, psum state assembly. At-rest state was already uint8."""
        return cls(at_rest="uint8", vmem="int32", wire="int32",
                   counter="int32", combine="psum")


DEFAULT = StateSpec()


def resolve(spec: "StateSpec | None") -> StateSpec:
    """Normalize an optional spec argument (None -> DEFAULT)."""
    return DEFAULT if spec is None else spec
