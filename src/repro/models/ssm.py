"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) blocks and LM.

Train path: chunked SSD — intra-chunk "attention-like" quadratic term with
decay masks + inter-chunk state recurrence (lax.scan over chunks). Decode
path: O(1) recurrent state update per token (the reason the ssm/hybrid archs
run the long_500k cell).

All decay exponentials are computed in f32 on non-positive arguments, so they
are bounded in (0, 1] — no overflow paths.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n
    return d_inner, heads, n, conv_ch


def init_ssm_layer(key, cfg: ModelConfig, stacked: int = 0) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    d = cfg.d_model
    d_inner, h, n, conv_ch = ssm_dims(cfg)
    p_total = 2 * d_inner + 2 * n + h
    lead = (stacked,) if stacked else ()
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.zeros(lead + (d,), dt),
        "in_proj": L.dense_init(ks[0], lead + (d, p_total), d, dt),
        "conv_w": L.dense_init(ks[1], lead + (cfg.ssm_conv_width, conv_ch), cfg.ssm_conv_width, dt),
        "conv_b": jnp.zeros(lead + (conv_ch,), dt),
        "A_log": jnp.zeros(lead + (h,), jnp.float32),
        "D_skip": jnp.ones(lead + (h,), jnp.float32),
        "dt_bias": jnp.zeros(lead + (h,), jnp.float32),
        "gate_norm": jnp.zeros(lead + (d_inner,), dt),
        "out_proj": L.dense_init(ks[2], lead + (d_inner, d), d_inner, dt),
    }


def _split_proj(proj, cfg):
    d_inner, h, n, _ = ssm_dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [W, C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
        for i in range(width)
    )
    return jax.nn.silu((out + b.astype(xbc.dtype)).astype(jnp.float32)).astype(xbc.dtype)


def ssd_scan(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H] f32 (post-softplus)
    a: jax.Array,    # [H] f32 (negative)
    bm: jax.Array,   # [B, S, N]
    cm: jax.Array,   # [B, S, N]
    chunk: int,
) -> jax.Array:
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = bm.reshape(b, nc, q, n).astype(jnp.float32)
    cr = cm.reshape(b, nc, q, n).astype(jnp.float32)

    da = dtr * a  # [b,nc,q,h], <= 0
    cum = jnp.cumsum(da, axis=2)

    # intra-chunk quadratic term
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((q, q), bool))
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)
    scores = cb[..., None] * decay * dtr[:, :, None, :, :]          # [b,nc,i,j,h]
    scores = jnp.where(tri[None, None, :, :, None], scores, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xr.astype(jnp.float32))

    # chunk-local end states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                     # [b,nc,q,h]
    s_loc = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", br, dtr * decay_end, xr.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                          # [b,nc,h]

    # inter-chunk recurrence
    def step(s_prev, inp):
        s_c, dk = inp  # [b,h,p,n], [b,h]
        s_new = dk[:, :, None, None] * s_prev + s_c
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(s_loc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                            # [b,nc,h,p,n]
    y_inter = (
        jnp.einsum("bcin,bchpn->bcihp", cr, s_prevs)
        * jnp.exp(cum)[..., None]
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype)


def ssm_layer_train(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    """One Mamba-2 block (pre-norm residual). x [B, S, D]."""
    b, s, d = x.shape
    d_inner, h, n, _ = ssm_dims(cfg)
    hnorm = L.rms_norm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dp->bsp", hnorm, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner].reshape(b, s, h, cfg.ssm_headdim)
    bm = xbc[..., d_inner : d_inner + n]
    cm = xbc[..., d_inner + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y = ssd_scan(xs, dt, a, bm, cm, cfg.ssm_chunk)
    y = y + xs * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   p["gate_norm"], cfg.norm_eps)
    return x + jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def init_ssm_cache(cfg: ModelConfig, batch: int, stacked: int) -> Dict[str, jax.Array]:
    d_inner, h, n, conv_ch = ssm_dims(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "conv": jnp.zeros((stacked, batch, cfg.ssm_conv_width - 1, conv_ch), dt),
        "ssm": jnp.zeros((stacked, batch, h, cfg.ssm_headdim, n), jnp.float32),
    }


def ssm_layer_decode(
    x: jax.Array,            # [B, 1, D]
    p: Dict[str, Any],
    conv_state: jax.Array,   # [B, W-1, conv_ch]
    ssm_state: jax.Array,    # [B, H, P, N]
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b = x.shape[0]
    d_inner, h, n, conv_ch = ssm_dims(cfg)
    hnorm = L.rms_norm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dp->bsp", hnorm, p["in_proj"].astype(x.dtype))[:, 0]
    z, xbc, dt_raw = _split_proj(proj, cfg)

    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype))
    conv_out = jax.nn.silu(
        (conv_out + p["conv_b"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xs = conv_out[..., :d_inner].reshape(b, h, cfg.ssm_headdim).astype(jnp.float32)
    bm = conv_out[..., d_inner : d_inner + n].astype(jnp.float32)
    cm = conv_out[..., d_inner + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                             # [B, H]
    new_state = da[:, :, None, None] * ssm_state + jnp.einsum(
        "bn,bh,bhp->bhpn", bm, dt, xs
    )
    y = jnp.einsum("bhpn,bn->bhp", new_state, cm) + xs * p["D_skip"][None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = L.rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
        p["gate_norm"], cfg.norm_eps,
    )
    out = x + jnp.einsum("bi,id->bd", y, p["out_proj"].astype(x.dtype))[:, None, :]
    return out, new_conv_state, new_state


# ------------------------------------------------------------- full LM -----
def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "embed": L.dense_init(k1, (cfg.vocab_size, cfg.d_model), cfg.d_model, dt),
        "blocks": init_ssm_layer(k2, cfg, stacked=cfg.num_layers),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k3, (cfg.d_model, cfg.vocab_size), cfg.d_model, dt)
    return params


def forward(params, tokens, cfg: ModelConfig, return_hidden: bool = False) -> jax.Array:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.batch_shard(params["embed"].astype(dt)[tokens])

    def block(x, bp):
        return ssm_layer_train(x, bp, cfg), None

    blk = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(blk, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if return_hidden:
        return x, head
    return L.lm_head(x, head)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    del max_len  # O(1) state — the point of the ssm family
    cache = init_ssm_cache(cfg, batch, cfg.num_layers)
    cache["cur"] = jnp.zeros((), jnp.int32)
    return cache


def prefill(params, tokens, cfg: ModelConfig, max_len=None):
    """Sequential prefill via scan over tokens would be O(S) steps; for the
    SSD family the standard trick is to run the chunked train-mode forward and
    rebuild the final recurrent state. Here we return logits + a cache primed
    by replaying the last conv window and running the chunked state scan."""
    logits = forward(params, tokens, cfg)
    b, s = tokens.shape
    cache = init_cache(cfg, b, s)
    cache["cur"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(dt)[tokens]

    def block(x, bp_state):
        bp, conv_s, ssm_s = bp_state
        x, conv_s, ssm_s = ssm_layer_decode(x, bp, conv_s, ssm_s, cfg)
        return x, (conv_s, ssm_s)

    x, (conv_ns, ssm_ns) = jax.lax.scan(
        block, x, (params["blocks"], cache["conv"], cache["ssm"])
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_head(x, head)
    new_cache = {"conv": conv_ns, "ssm": ssm_ns, "cur": cache["cur"] + 1}
    return logits, new_cache
