"""Shared neural layers: norms, RoPE / M-RoPE, GQA attention (train: chunked
online-softmax "flash in XLA"; decode: cached, optionally rolling-window),
gated MLPs.

Everything is functional: params are plain dict pytrees, all layer params may
carry a leading stacked [L, ...] dim consumed by lax.scan in the model files.
Compute dtype is bf16 with f32 softmax/norm accumulations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...]-> cos/sin [..., head_dim//2] (f32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(
    positions: jax.Array,  # [3, B, S] (t, h, w)
    head_dim: int,
    theta: float,
    sections: Tuple[int, ...],
) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE: the head_dim//2 frequency slots are split
    into (t, h, w) sections, each driven by its own position stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id per frequency slot
    sec_id = np.repeat(np.arange(len(sections)), sections)  # [half]
    pos_per_freq = positions.astype(jnp.float32)[sec_id]    # [half, B, S]
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * freqs          # [B, S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, hd]; cos/sin [B, S, hd//2] -> rotated x (same dtype)."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def gqa_attention_chunked(
    q: jax.Array,   # [B, S, Hq, hd]
    k: jax.Array,   # [B, S, Hkv, hd]
    v: jax.Array,   # [B, S, Hkv, hd]
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax chunked attention (bounded memory at any S: the pure-XLA
    analogue of the flash kernel; the Pallas kernel in kernels/flash_attention
    is the TPU-optimized drop-in)."""
    b, s, hq, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    qc = min(q_chunk, s)
    if s % qc:
        qc = s  # fall back to unchunked when the length doesn't tile
    kc = min(kv_chunk, sk)
    if sk % kc:
        kc = sk
    nq = s // qc
    nk = sk // kc
    if causal or window:
        assert s == sk, "causal/window attention requires equal q/kv lengths"

    qr = q.reshape(b, nq, qc, hkv, g, hd)
    kr = k.reshape(b, nk, kc, hkv, hd)
    vr = v.reshape(b, nk, kc, hkv, hd)

    def q_step(_, qi_q):
        qi, qblk = qi_q  # qblk [B, qc, Hkv, G, hd]
        qs = qblk * jnp.asarray(scale, qblk.dtype)
        q_pos = qi * qc + jnp.arange(qc)

        def kv_step(carry, ki_kv):
            m_i, l_i, acc = carry
            ki, kblk, vblk = ki_kv
            # bf16 operands, f32 accumulation — explicit .astype(f32) on K/V
            # would materialize full-precision copies of the cache chunks
            scores = jnp.einsum(
                "bqkgd,btkd->bkgqt", qs, kblk,
                preferred_element_type=jnp.float32,
            )  # [B,Hkv,G,qc,kc]
            kv_pos = ki * kc + jnp.arange(kc)
            mask = jnp.ones((qc, kc), jnp.bool_)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m_i, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m_i - m_new)
            l_new = l_i * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)
        (m_i, l_i, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)),
        )
        l_safe = jnp.where(l_i > 0, l_i, 1.0)
        out = (acc / l_safe[..., None]).astype(q.dtype)  # [B,Hkv,G,qc,hd]
        return None, out

    _, outs = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0))
    )
    # outs [nq, B, Hkv, G, qc, hd] -> [B, S, Hq, hd]
    out = jnp.moveaxis(outs, 0, 3)               # [B,Hkv,G,nq,qc,hd]
    out = out.reshape(b, hkv, g, s, hd)
    out = jnp.moveaxis(out, 3, 1)                # [B,S,Hkv,G,hd]
    return out.reshape(b, s, hq, hd)


def gqa_attention_decode(
    q: jax.Array,        # [B, 1, Hq, hd]
    k_cache: jax.Array,  # [B, W, Hkv, hd]
    v_cache: jax.Array,  # [B, W, Hkv, hd]
    cache_pos: jax.Array,  # int32[W] position of each cache slot (-1 empty)
    cur_pos: jax.Array,    # scalar current position
    *,
    window: int = 0,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode attention over a (possibly rolling) KV cache."""
    b, _, hq, hd = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    # KV operands stay in storage dtype (bf16); f32 accumulation via
    # preferred_element_type. An explicit .astype(f32) on the cache would
    # materialize (stacked over scanned layers) a full-precision copy of the
    # entire cache: observed +7.9 GiB/device on llama3-405b decode_32k.
    qs = q.reshape(b, hkv, g, hd) * jnp.asarray(scale, q.dtype)
    scores = jnp.einsum(
        "bkgd,bwkd->bkgw", qs, k_cache, preferred_element_type=jnp.float32
    )
    mask = (cache_pos >= 0) & (cache_pos <= cur_pos)
    if window > 0:
        mask &= cache_pos > cur_pos - window
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgw,bwkd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------- mlps -----
def gated_mlp(x, w_gate, w_up, w_down, act: str = "swiglu",
              b_gate=None, b_up=None, b_down=None):
    h_gate = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    if b_gate is not None:
        h_gate = h_gate + b_gate.astype(x.dtype)
    if act == "swiglu":
        h_up = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
        if b_up is not None:
            h_up = h_up + b_up.astype(x.dtype)
        h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    elif act == "gelu":
        h = jax.nn.gelu(h_gate.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(act)
    out = jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))
    if b_down is not None:
        out = out + b_down.astype(x.dtype)
    return out


# ------------------------------------------------------------- initutil ----
def dense_init(key, shape, in_axis_size, dtype):
    scale = (1.0 / max(in_axis_size, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- head -----
def lm_head(x: jax.Array, head_w: jax.Array, transpose: bool = False) -> jax.Array:
    """Final projection to vocab. Keeps bf16 (CE upcasts per-shard) and pins
    the vocab dim to the "model" axis so the [B, S, V] tensor — the largest
    activation of every LM — never materializes replicated. No-ops without a
    mesh context."""
    from repro.parallel.sharding import constrain
    from jax.sharding import PartitionSpec as P
    eq = "bsd,vd->bsv" if transpose else "bsd,dv->bsv"
    logits = jnp.einsum(eq, x, head_w.astype(x.dtype))
    return constrain(logits, P(("pod", "data"), None, "model"))


def batch_shard(x: jax.Array) -> jax.Array:
    """Constrain the leading (batch) dim to the data axes. No-op without a
    mesh context."""
    from repro.parallel.sharding import constrain
    from jax.sharding import PartitionSpec as P
    spec = [("pod", "data")] + [None] * (x.ndim - 1)
    return constrain(x, P(*spec))


def seq_shard(x: jax.Array) -> jax.Array:
    """Megatron-style sequence parallelism pin: [B, S, D] residual sharded
    (batch -> data axes, seq -> model axis). No-op without a mesh."""
    from repro.parallel.sharding import constrain
    from jax.sharding import PartitionSpec as P
    return constrain(x, P(("pod", "data"), "model", None))
