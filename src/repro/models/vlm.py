"""Qwen2-VL backbone (arXiv:2409.12191): the assigned entry is the
transformer BACKBONE; the vision tower is a STUB — ``input_specs()`` supplies
precomputed patch embeddings [B, S_img, D] which are prefixed to the text
tokens, plus M-RoPE position ids [3, B, S] (temporal / height / width
streams, dynamic-resolution ready).

Everything else delegates to models/transformer.py with
cfg.mrope_sections set.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

init_params = T.init_params
init_cache = T.init_cache


def make_mrope_positions(
    batch: int, seq: int, num_image_tokens: int, grid_hw: Tuple[int, int]
) -> jnp.ndarray:
    """Build [3, B, S] (t, h, w) positions: image patches get (0, y, x); text
    tokens continue with equal t/h/w ids after the image (Qwen2-VL scheme)."""
    gh, gw = grid_hw
    assert gh * gw == num_image_tokens
    ys = jnp.repeat(jnp.arange(gh), gw)
    xs = jnp.tile(jnp.arange(gw), gh)
    t_img = jnp.zeros((num_image_tokens,), jnp.int32)
    n_text = seq - num_image_tokens
    start = max(gh, gw)
    text = start + jnp.arange(n_text, dtype=jnp.int32)
    pos_t = jnp.concatenate([t_img, text])
    pos_h = jnp.concatenate([ys.astype(jnp.int32), text])
    pos_w = jnp.concatenate([xs.astype(jnp.int32), text])
    pos = jnp.stack([pos_t, pos_h, pos_w])          # [3, S]
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def forward(
    params: Dict[str, Any],
    tokens: jax.Array,            # [B, S_text]
    image_embeds: jax.Array,      # [B, S_img, D]
    mrope_positions: jax.Array,   # [3, B, S_img + S_text]
    cfg: ModelConfig,
    return_hidden: bool = False,
) -> jax.Array:
    return T.forward(
        params, tokens, cfg,
        mrope_positions=mrope_positions,
        extra_embeds=image_embeds,
        return_hidden=return_hidden,
    )


def prefill(params, tokens, image_embeds, mrope_positions, cfg, max_len=None):
    return T.prefill(
        params, tokens, cfg, max_len=max_len,
        mrope_positions=mrope_positions, extra_embeds=image_embeds,
    )


decode_step = T.decode_step
