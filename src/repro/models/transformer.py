"""Decoder-only transformer LM: dense (llama/qwen), MoE (mixtral/granite),
and VLM backbone (qwen2-vl with M-RoPE + stubbed vision frontend).

Layers are scanned (stacked [L, ...] params) so 126-layer models lower to a
compact HLO; per-layer remat is the default memory policy at scale.

Three entry points per model — the dry-run lowers exactly these:
  * train:   ``forward`` (+ loss/grad/optimizer in launch/train.py)
  * prefill: ``prefill``  — forward returning a filled KV cache
  * decode:  ``decode_step`` — one token against the cache (rolling window
             buffer when cfg.sliding_window > 0, so SWA archs decode 500k
             contexts with a bounded cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe_mlp, moe_mlp


Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------------ init ---
def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    keys = jax.random.split(key, 16)

    def dense(k, shape, fan_in):
        return L.dense_init(k, shape, fan_in, dt)

    attn = {
        "wq": dense(keys[0], (l, d, hq * hd), d),
        "wk": dense(keys[1], (l, d, hkv * hd), d),
        "wv": dense(keys[2], (l, d, hkv * hd), d),
        "wo": dense(keys[3], (l, hq * hd, d), hq * hd),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((l, hq * hd), dt)
        attn["bk"] = jnp.zeros((l, hkv * hd), dt)
        attn["bv"] = jnp.zeros((l, hkv * hd), dt)

    if cfg.num_experts > 0:
        mlp = init_moe_mlp(keys[4], cfg, stacked=l)
    else:
        mlp = {
            "w_gate": dense(keys[5], (l, d, cfg.d_ff), d),
            "w_up": dense(keys[6], (l, d, cfg.d_ff), d),
            "w_down": dense(keys[7], (l, cfg.d_ff, d), cfg.d_ff),
        }

    params = {
        "embed": dense(keys[8], (cfg.vocab_size, d), d),
        "blocks": {
            "attn": attn,
            "mlp": mlp,
            "norm1": jnp.zeros((l, d), dt),
            "norm2": jnp.zeros((l, d), dt),
        },
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense(keys[9], (d, cfg.vocab_size), d)
    return params


# ------------------------------------------------------------- attention ---
def _attn_train(x, p, cfg: ModelConfig, cos, sin):
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)
    out = L.gqa_attention_chunked(
        q, k, v, causal=True, window=cfg.sliding_window
    )
    return jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * hd), p["wo"].astype(x.dtype)), k, v


def _attn_decode(x, p, cfg: ModelConfig, cos, sin, k_cache, v_cache, cache_pos, cur):
    b, s, d = x.shape  # s == 1
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = L.apply_rotary(q.reshape(b, 1, hq, hd), cos, sin)
    k = L.apply_rotary(k.reshape(b, 1, hkv, hd), cos, sin)
    v = v.reshape(b, 1, hkv, hd)
    # rolling write slot
    w = k_cache.shape[1]
    slot = cur % w
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    out = L.gqa_attention_decode(
        q, k_cache, v_cache, cache_pos, cur, window=cfg.sliding_window
    )
    o = jnp.einsum("bsh,hd->bsd", out.reshape(b, 1, hq * hd), p["wo"].astype(x.dtype))
    return o, k_cache, v_cache


def _mlp(x, p, cfg: ModelConfig):
    if cfg.num_experts > 0:
        return moe_mlp(x, p, cfg)
    return L.gated_mlp(x, p["w_gate"], p["w_up"], p["w_down"], act=cfg.act)


# -------------------------------------------------------------- forward ----
def _rope(cfg: ModelConfig, positions, mrope_positions=None):
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections and mrope_positions is not None:
        return L.mrope_cos_sin(mrope_positions, hd, cfg.rope_theta, cfg.mrope_sections)
    return L.rope_cos_sin(positions, hd, cfg.rope_theta)


def _embed_inputs(params, cfg, tokens, extra_embeds):
    x = params["embed"].astype(_dtype(cfg))[tokens]
    if extra_embeds is not None:
        # VLM stub: precomputed patch embeddings prefixed to the text tokens
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    # re-pin batch sharding: the gather from the (vocab/d)-sharded table would
    # otherwise leave x replicated over the batch axes
    return L.batch_shard(x)


def forward(
    params: Params,
    tokens: jax.Array,                 # [B, S_text]
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,       # [B, S]
    mrope_positions: Optional[jax.Array] = None,  # [3, B, S]
    extra_embeds: Optional[jax.Array] = None,     # [B, S_img, D]
    return_hidden: bool = False,
) -> jax.Array:
    """Full-sequence forward -> logits [B, S, V], or (hidden, head) when
    return_hidden (the chunked-CE loss path never materializes full logits)."""
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cos, sin = _rope(cfg, positions, mrope_positions)

    def block(x, bp):
        h, _, _ = _attn_train(L.rms_norm(x, bp["norm1"]), bp["attn"], cfg, cos, sin)
        x = x + h
        x = x + _mlp(L.rms_norm(x, bp["norm2"]), bp["mlp"], cfg)
        if cfg.seq_sharded_residual:
            x = L.seq_shard(x)
        return x, None

    blk = jax.checkpoint(block) if cfg.remat else block
    if cfg.seq_sharded_residual:
        x = L.seq_shard(x)
    x, _ = jax.lax.scan(blk, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if return_hidden:
        return x, head
    return L.lm_head(x, head)


# ---------------------------------------------------------------- cache ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jax.Array]:
    """KV cache; rolling-window-sized for SWA archs."""
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    hkv, hd, l = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((l, batch, w, hkv, hd), dt),
        "v": jnp.zeros((l, batch, w, hkv, hd), dt),
        "pos": jnp.full((w,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }


def prefill(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    max_len: Optional[int] = None,
    mrope_positions: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward pass that also materializes the KV cache (inference prefill)."""
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    b, s, _ = x.shape
    max_len = max_len or s
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cos, sin = _rope(cfg, positions, mrope_positions)

    def block(x, bp):
        h, k, v = _attn_train(L.rms_norm(x, bp["norm1"]), bp["attn"], cfg, cos, sin)
        x = x + h
        x = x + _mlp(L.rms_norm(x, bp["norm2"]), bp["mlp"], cfg)
        # keep the last `w` positions in the cache (rolling window layout:
        # cache slot = pos % w, which for pos in [s-w, s) is a rotation)
        kk = k[:, -w:] if s >= w else jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        vv = v[:, -w:] if s >= w else jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        if s >= w:
            start = s - w
            pos_tail = start + jnp.arange(w, dtype=jnp.int32)
            shift = start % w
            kk = jnp.roll(kk, shift, axis=1)
            vv = jnp.roll(vv, shift, axis=1)
        return x, (kk, vv)

    blk = jax.checkpoint(block) if cfg.remat else block
    x, (ks, vs) = jax.lax.scan(blk, x, params["blocks"])
    xn = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_head(xn, head)

    if s >= w:
        start = s - w
        idx = jnp.arange(w, dtype=jnp.int32)
        pos = start + ((idx - start) % w)  # slot i holds position start+((i-start)%w)
    else:
        pos = jnp.where(jnp.arange(w) < s, jnp.arange(w), -1).astype(jnp.int32)
    cache = {"k": ks, "v": vs, "pos": pos, "cur": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(
    params: Params,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,   # [B, 1]
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against the cache. Returns (logits [B,1,V], cache)."""
    dt = _dtype(cfg)
    x = params["embed"].astype(dt)[tokens]
    b = x.shape[0]
    cur = cache["cur"]
    positions = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
    if cfg.mrope_sections:
        mpos = jnp.broadcast_to(cur, (3, b, 1)).astype(jnp.int32)
        cos, sin = _rope(cfg, positions, mpos)
    else:
        cos, sin = _rope(cfg, positions)
    w = cache["k"].shape[2]
    cache_pos = cache["pos"].at[cur % w].set(cur)

    def block(x, bp_kv):
        bp, kc, vc = bp_kv
        h, kc, vc = _attn_decode(
            L.rms_norm(x, bp["norm1"]), bp["attn"], cfg, cos, sin, kc, vc,
            cache_pos, cur,
        )
        x = x + h
        x = x + _mlp(L.rms_norm(x, bp["norm2"]), bp["mlp"], cfg)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(block, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_head(x, head)
    new_cache = {"k": ks, "v": vs, "pos": cache_pos, "cur": cur + 1}
    return logits, new_cache
