"""Mixture-of-Experts FFN with two routers:

* ``topk``    — standard token-choice top-k (Mixtral/GShard baseline);
  capacity overflow -> token dropped at that expert (the classic failure the
  paper-technique router avoids).
* ``skipper`` — the paper's technique as a first-class feature: token-expert
  assignment as a *capacity-constrained maximal b-matching* over the
  score-sorted candidate edge stream, computed by the shared claim engine's
  capacitated first-K-claim rounds (core/bipartite.py -> core/engine.py,
  DESIGN.md §9). Capacity is respected by construction — no token ever
  silently dropped at dispatch; conflicts (two tokens claiming the last slot
  of an expert) are resolved just-in-time inside the tile, not by iterative
  re-balancing (Sinkhorn/auction) passes — and the accepted set is exactly
  the sequential greedy over the score order.

Dispatch is group-local: tokens are split into G groups of ``group_tokens``
(aligned with the data shards at scale, the standard per-shard capacity
semantics), and the matching/vectorized routing is vmapped over groups —
no sequential chain longer than (group_tokens * k' / tile) tiles.

Expert compute is grouped GEMMs over a [E, C, D] capacity buffer built by
scatter, combined back with router weights by gather — the
sort-free static-shape dropless-style pipeline.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.bipartite import bmatch_assign
from repro.models import layers as L

GROUP_TOKENS = 4096      # routing group size (per-shard capacity domain)
MATCH_TILE = 512         # first-claim tile inside the matcher


def init_moe_mlp(key, cfg: ModelConfig, stacked: int = 0) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = (stacked,) if stacked else ()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": L.dense_init(k1, lead + (d, e), d, jnp.float32),
        "experts_gate": L.dense_init(k2, lead + (e, d, f), d, dt),
        "experts_up": L.dense_init(k3, lead + (e, d, f), d, dt),
        "experts_down": L.dense_init(k4, lead + (e, f, d), f, dt),
    }


def _route_group_topk(scores, k):
    """scores [N, E] -> (expert_ids [N*k], weights [N*k]) candidate edges in
    per-token top-k order; weights are softmax over the chosen k."""
    n, e = scores.shape
    vals, idx = jax.lax.top_k(scores, k)            # [N, k]
    w = jax.nn.softmax(vals, axis=-1)
    return idx.reshape(-1), w.reshape(-1).astype(jnp.float32), jnp.ones((n * k,), bool)


def _route_group_skipper(scores, k, capacity, num_candidates):
    """Skipper b-matching routing for one token group.

    scores [N, E] (f32). Returns (expert_ids [M], weights [M], accept [M])
    with M = N * num_candidates, in score-sorted stream order mapped back to
    per-token candidate order.
    """
    n, e = scores.shape
    kp = num_candidates
    vals, idx = jax.lax.top_k(scores, kp)           # [N, kp] candidates
    tok = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, kp))
    flat_tok = tok.reshape(-1)
    flat_exp = idx.reshape(-1).astype(jnp.int32)
    flat_val = vals.reshape(-1)
    # The assignment is discrete: no gradient flows through the matcher.
    # stop_gradient keeps the vjp machinery out of the (vmapped) sort/scan
    # index pipeline; router learning signal flows through the top-k `vals`
    # in the accepted-candidate softmax below — standard MoE practice.
    sg = jax.lax.stop_gradient
    order = jnp.argsort(-sg(flat_val))               # best edges first
    # vector_rounds is left at the engine's documented default
    # (bipartite.BMATCH_VECTOR_ROUNDS): the output is rounds-invariant
    # (exact-fallback fixpoint, test-pinned), and under this vmap the
    # while_loop fallback costs every group the batch-max iteration count —
    # exactly what the default's second unrolled round avoids.
    acc_sorted = bmatch_assign(
        sg(flat_tok[order]),
        sg(flat_exp[order]),
        num_tokens=n,
        num_experts=e,
        token_budget=k,
        expert_capacity=capacity,
        tile_size=MATCH_TILE,
    )
    accept = jnp.zeros((n * kp,), bool).at[order].set(acc_sorted)
    accept = sg(accept)
    # renormalize accepted scores per token (softmax over accepted candidates)
    gated = jnp.where(accept, flat_val, -jnp.inf).reshape(n, kp)
    w = jax.nn.softmax(gated, axis=-1)
    w = jnp.where(jnp.isfinite(gated), w, 0.0)
    return flat_exp, w.reshape(-1).astype(jnp.float32), accept


def moe_mlp(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    """x [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n_total = b * s
    xf = x.reshape(n_total, d)

    g_tokens = min(GROUP_TOKENS, n_total)
    assert n_total % g_tokens == 0, (n_total, g_tokens)
    g = n_total // g_tokens
    # per-group expert capacity (per-shard capacity domain)
    cap = int(g_tokens * k / e * cfg.moe_capacity_factor)
    cap = max(8, ((cap + 7) // 8) * 8)

    scores = jnp.einsum(
        "nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    scores = jax.nn.log_softmax(scores, axis=-1)
    scores_g = scores.reshape(g, g_tokens, e)

    if cfg.moe_router == "skipper":
        kp = min(e, k + 2)
        route = jax.vmap(
            partial(_route_group_skipper, k=k, capacity=cap, num_candidates=kp)
        )
        exp_ids, weights, accept = route(scores_g)      # [G, g_tokens*kp]
    else:
        kp = k
        route = jax.vmap(partial(_route_group_topk, k=k))
        exp_ids, weights, accept = route(scores_g)

    m_g = g_tokens * kp
    tok_local = jnp.broadcast_to(
        (jnp.arange(m_g, dtype=jnp.int32) // kp)[None], (g, m_g)
    )

    # --- slot assignment within (group, expert): rank among accepted edges --
    # pure integer work: flat composite-key sort ((group, expert) segments),
    # under stop_gradient like the rest of the index pipeline.
    def slots_flat(eid, acc):
        gid = jnp.repeat(jnp.arange(g, dtype=jnp.int32), m_g)
        key = jnp.where(acc.reshape(-1), gid * (e + 1) + eid.reshape(-1), g * (e + 1))
        order = jnp.argsort(key, stable=True)
        sorted_key = key[order]
        starts = jnp.searchsorted(sorted_key, jnp.arange(g * (e + 1) + 1))
        slot_sorted = (
            jnp.arange(g * m_g, dtype=jnp.int32) - starts[sorted_key].astype(jnp.int32)
        )
        slot_of = jnp.zeros((g * m_g,), jnp.int32).at[order].set(slot_sorted)
        return slot_of.reshape(g, m_g)

    slots = jax.lax.stop_gradient(slots_flat(exp_ids, accept))   # [G, M_g]
    ok = accept & (slots < cap) & (weights > 0)

    # --- flatten to global scatter/gather indices ---------------------------
    g_ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None], (g, m_g))
    tok_global = (g_ids * g_tokens + tok_local).reshape(-1)
    col = (g_ids * cap + slots).reshape(-1)              # [G*M_g] in [0, G*cap)
    exp_flat = exp_ids.reshape(-1)
    w_flat = weights.reshape(-1)
    ok_flat = ok.reshape(-1)
    c_total = g * cap

    from repro.parallel.sharding import constrain
    from jax.sharding import PartitionSpec as P

    # --- dispatch + expert GEMMs + combine -----------------------------------
    # Dispatch/combine run SHARD-LOCALLY (shard_map over the data axes):
    # groups are contiguous token blocks, so every edge's token AND buffer
    # column live on the same data shard — local scatter-adds with local
    # indices (scatter-ADD, not set: set's VJP builds full-buffer u32 masks,
    # observed 3x30 GiB on granite train). Letting the SPMD partitioner
    # handle these data-dependent gathers instead costs full-size mask
    # all-reduces (measured 1.9 GiB f32[M, D] all-reduces per layer).
    # The expert GEMMs stay at jit level: C over data axes, expert-hidden F
    # over "model" (TP-MoE partial-sum all-reduce once per layer).
    # [Hypothesis log, EXPERIMENTS §Perf: slot-parallel C over data x model
    # with replicated fine-grained experts — REFUTED: resharding churn made
    # memory (25.7 -> 138 GiB) and collectives (~2x) worse.]
    buf = _dispatch(xf, exp_flat, col, tok_global, ok_flat, e, c_total, d)
    buf = constrain(buf, P(None, ("pod", "data"), None))

    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["experts_gate"].astype(x.dtype))
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["experts_up"].astype(x.dtype))
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    h = constrain(h, P(None, ("pod", "data"), "model"))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["experts_down"].astype(x.dtype))
    y_buf = constrain(y_buf, P(None, ("pod", "data"), None))

    out = _combine(y_buf, xf, exp_flat, col, tok_global, ok_flat, w_flat)
    out = constrain(out, P(("pod", "data"), None))
    return out.reshape(b, s, d)


def _mesh_data_axes():
    """(mesh, data axes, shard count) if a >1-shard mesh is in scope."""
    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return None, (), 1
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return None, (), 1
        sizes = dict(mesh.shape)
        n = 1
        for a in axes:
            n *= sizes[a]
        return (mesh, axes, n) if n > 1 else (None, (), 1)
    except Exception:
        return None, (), 1


def _axis_idx(axes):
    try:
        return jax.lax.axis_index(axes)     # tuple form: flattened index
    except Exception:
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx


def _dispatch(xf, exp_flat, col, tok_global, ok_flat, e, c_total, d):
    """buf[e, c] = x[token] for accepted edges — shard-local when possible."""
    from jax.sharding import PartitionSpec as P

    mesh, axes, shards = _mesh_data_axes()
    n_total = xf.shape[0]
    m = exp_flat.shape[0]
    if mesh is None or n_total % shards or m % shards or c_total % shards:
        gathered = jnp.where(ok_flat[:, None], xf[tok_global], 0)
        buf = jnp.zeros((e, c_total, d), xf.dtype)
        return buf.at[
            jnp.where(ok_flat, exp_flat, e), jnp.where(ok_flat, col, 0)
        ].add(gathered, mode="drop")
    n_loc, c_loc = n_total // shards, c_total // shards

    def body(xf_l, exp_l, col_l, tok_l, ok_l):
        sid = _axis_idx(axes)
        tok_rel = tok_l[0] - sid * n_loc
        col_rel = col_l[0] - sid * c_loc
        local = (
            ok_l[0]
            & (tok_rel >= 0) & (tok_rel < n_loc)
            & (col_rel >= 0) & (col_rel < c_loc)
        )
        gathered = jnp.where(
            local[:, None], xf_l[0][jnp.clip(tok_rel, 0, n_loc - 1)], 0
        )
        buf_l = jnp.zeros((e, c_loc, d), xf_l.dtype)
        buf_l = buf_l.at[
            jnp.where(local, exp_l[0], e), jnp.where(local, col_rel, 0)
        ].add(gathered, mode="drop")
        return buf_l[:, None]  # reinsert the sharded C axis block dim

    out = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None, None), P(axes, None), P(axes, None),
                  P(axes, None), P(axes, None)),
        out_specs=P(None, axes, None, None),
        check_vma=False,
    )(
        xf.reshape(shards, n_loc, d),
        exp_flat.reshape(shards, m // shards),
        col.reshape(shards, m // shards),
        tok_global.reshape(shards, m // shards),
        ok_flat.reshape(shards, m // shards),
    )
    return out.reshape(e, c_total, d)


def _combine(y_buf, xf, exp_flat, col, tok_global, ok_flat, w_flat):
    """out[token] += w * y_buf[e, c] — shard-local when possible."""
    from jax.sharding import PartitionSpec as P

    mesh, axes, shards = _mesh_data_axes()
    n_total, d = xf.shape
    e, c_total, _ = y_buf.shape
    m = exp_flat.shape[0]
    if mesh is None or n_total % shards or m % shards or c_total % shards:
        contrib = y_buf[
            jnp.where(ok_flat, exp_flat, 0), jnp.where(ok_flat, col, 0)
        ] * jnp.where(ok_flat, w_flat, 0.0)[:, None].astype(y_buf.dtype)
        return jnp.zeros((n_total, d), y_buf.dtype).at[tok_global].add(contrib)
    n_loc, c_loc = n_total // shards, c_total // shards

    def body(y_l, exp_l, col_l, tok_l, ok_l, w_l):
        sid = _axis_idx(axes)
        tok_rel = tok_l[0] - sid * n_loc
        col_rel = col_l[0] - sid * c_loc
        local = (
            ok_l[0]
            & (tok_rel >= 0) & (tok_rel < n_loc)
            & (col_rel >= 0) & (col_rel < c_loc)
        )
        contrib = y_l[:, 0][
            jnp.where(local, exp_l[0], 0), jnp.where(local, col_rel, 0)
        ] * jnp.where(local, w_l[0], 0.0)[:, None].astype(y_l.dtype)
        out_l = jnp.zeros((n_loc, d), y_l.dtype).at[
            jnp.where(local, tok_rel, n_loc)
        ].add(contrib, mode="drop")
        return out_l[None]

    out = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axes, None, None), P(axes, None), P(axes, None),
                  P(axes, None), P(axes, None), P(axes, None)),
        out_specs=P(axes, None, None),
        check_vma=False,
    )(
        y_buf.reshape(e, shards, c_loc, d),
        exp_flat.reshape(shards, m // shards),
        col.reshape(shards, m // shards),
        tok_global.reshape(shards, m // shards),
        ok_flat.reshape(shards, m // shards),
        w_flat.reshape(shards, m // shards),
    )
    return out.reshape(n_total, d)
