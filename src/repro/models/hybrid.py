"""Zamba2-style hybrid LM (arXiv:2411.15242): a stack of Mamba-2 blocks with
ONE shared attention block (single parameter copy) applied every
``shared_attn_period`` layers — the Zamba weight-sharing trick that buys
attention quality at SSM memory cost. KV cache exists only at the ~L/period
application points, which is why this arch runs the long_500k decode cell.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S


def _n_apps(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_period


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 12)
    n_apps = _n_apps(cfg)
    period = cfg.shared_attn_period
    assert n_apps * period == cfg.num_layers

    shared = {
        "attn": {
            "wq": L.dense_init(ks[0], (d, hq * hd), d, dt),
            "wk": L.dense_init(ks[1], (d, hkv * hd), d, dt),
            "wv": L.dense_init(ks[2], (d, hkv * hd), d, dt),
            "wo": L.dense_init(ks[3], (hq * hd, d), hq * hd, dt),
        },
        "mlp": {
            "w_gate": L.dense_init(ks[4], (d, cfg.d_ff), d, dt),
            "w_up": L.dense_init(ks[5], (d, cfg.d_ff), d, dt),
            "w_down": L.dense_init(ks[6], (cfg.d_ff, d), cfg.d_ff, dt),
        },
        "norm1": jnp.zeros((d,), dt),
        "norm2": jnp.zeros((d,), dt),
    }
    # ssm blocks stacked as [n_apps, period, ...] for the two-level scan
    ssm_blocks = S.init_ssm_layer(ks[7], cfg, stacked=cfg.num_layers)
    ssm_blocks = jax.tree.map(
        lambda x: x.reshape((n_apps, period) + x.shape[1:]), ssm_blocks
    )
    params = {
        "embed": L.dense_init(ks[8], (cfg.vocab_size, d), d, dt),
        "ssm_blocks": ssm_blocks,
        "shared": shared,
        "final_norm": jnp.zeros((d,), dt),
        "lm_head": L.dense_init(ks[9], (d, cfg.vocab_size), d, dt),
    }
    return params


def _shared_attn_train(x, p, cfg, cos, sin):
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)
    o = L.gqa_attention_chunked(q, k, v, causal=True)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, s, hq * hd), p["attn"]["wo"].astype(x.dtype))
    h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x, k, v


def forward(params, tokens, cfg: ModelConfig, return_hidden: bool = False) -> jax.Array:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.batch_shard(params["embed"].astype(dt)[tokens])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)

    def group(x, gp):
        def inner(x, bp):
            return S.ssm_layer_train(x, bp, cfg), None

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner
        x, _ = jax.lax.scan(inner_fn, x, gp)
        x, _, _ = _shared_attn_train(x, params["shared"], cfg, cos, sin)
        return x, None

    grp = jax.checkpoint(group) if cfg.remat else group
    x, _ = jax.lax.scan(grp, x, params["ssm_blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, params["lm_head"]
    return L.lm_head(x, params["lm_head"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    n_apps = _n_apps(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = S.init_ssm_cache(cfg, batch, cfg.num_layers)
    cache = {
        "conv": cache["conv"].reshape(
            (n_apps, cfg.shared_attn_period) + cache["conv"].shape[1:]
        ),
        "ssm": cache["ssm"].reshape(
            (n_apps, cfg.shared_attn_period) + cache["ssm"].shape[1:]
        ),
        "k": jnp.zeros((n_apps, batch, max_len, hkv, hd), dt),
        "v": jnp.zeros((n_apps, batch, max_len, hkv, hd), dt),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }
    return cache


def prefill(params, tokens, cfg: ModelConfig, max_len=None):
    b, s = tokens.shape
    max_len = max_len or s
    logits = forward(params, tokens, cfg)  # cache rebuild below
    cache = init_cache(cfg, b, max_len)
    cache["cur"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(dt)[tokens]
    b = x.shape[0]
    cur = cache["cur"]
    positions = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    w = cache["k"].shape[2]
    cache_pos = cache["pos"].at[cur % w].set(cur)
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    sp = params["shared"]

    def group(x, gp_kv):
        gp, conv_s, ssm_s, kc, vc = gp_kv

        def inner(x, bp_state):
            bp, cs, ss = bp_state
            x, cs, ss = S.ssm_layer_decode(x, bp, cs, ss, cfg)
            return x, (cs, ss)

        x, (conv_ns, ssm_ns) = jax.lax.scan(inner, x, (gp, conv_s, ssm_s))
        # shared attention application
        h = L.rms_norm(x, sp["norm1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, sp["attn"]["wq"].astype(x.dtype)).reshape(b, 1, hq, hd)
        k = jnp.einsum("bsd,dh->bsh", h, sp["attn"]["wk"].astype(x.dtype)).reshape(b, 1, hkv, hd)
        v = jnp.einsum("bsd,dh->bsh", h, sp["attn"]["wv"].astype(x.dtype)).reshape(b, 1, hkv, hd)
        q = L.apply_rotary(q, cos, sin)
        k = L.apply_rotary(k, cos, sin)
        slot = cur % w
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        o = L.gqa_attention_decode(q, kc, vc, cache_pos, cur)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, hq * hd), sp["attn"]["wo"].astype(x.dtype))
        h2 = L.rms_norm(x, sp["norm2"], cfg.norm_eps)
        x = x + L.gated_mlp(h2, sp["mlp"]["w_gate"], sp["mlp"]["w_up"], sp["mlp"]["w_down"])
        return x, (conv_ns, ssm_ns, kc, vc)

    x, (conv_ns, ssm_ns, ks, vs) = jax.lax.scan(
        group, x,
        (params["ssm_blocks"], cache["conv"], cache["ssm"], cache["k"], cache["v"]),
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["lm_head"])
    new_cache = {
        "conv": conv_ns, "ssm": ssm_ns, "k": ks, "v": vs,
        "pos": cache_pos, "cur": cur + 1,
    }
    return logits, new_cache
