"""Model zoo: one module per family; configs/registry.py binds arch ids to
(family module, ModelConfig). Every family exposes init_params / forward and,
where decoding exists, init_cache / prefill / decode_step.
"""
from repro.models import transformer, moe, ssm, hybrid, encdec, vlm, layers

FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,   # MoE runs through transformer.py with expert MLPs
    "vlm": vlm,
    "audio": encdec,
    "ssm": ssm,
    "hybrid": hybrid,
}

__all__ = ["transformer", "moe", "ssm", "hybrid", "encdec", "vlm", "layers", "FAMILY_MODULES"]
