"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed mel-frame embeddings [B, T_enc, D] (what the two stride-2 convs
would produce). Encoder: bidirectional MHA + GELU MLP, sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU MLP, learned
positions, tied embedding head.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L

MAX_DECODER_POS = 65536


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def sinusoids(length: int, channels: int) -> np.ndarray:
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    ang = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def _init_attn(keys, d, hq, hkv, hd, dt, prefix=""):
    return {
        prefix + "wq": L.dense_init(keys[0], (d, hq * hd), d, dt),
        prefix + "wk": L.dense_init(keys[1], (d, hkv * hd), d, dt),
        prefix + "wv": L.dense_init(keys[2], (d, hkv * hd), d, dt),
        prefix + "wo": L.dense_init(keys[3], (hq * hd, d), hq * hd, dt),
    }


def _ln_init(lead, d, dt):
    return {"scale": jnp.ones(lead + (d,), dt), "bias": jnp.zeros(lead + (d,), dt)}


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dt(cfg)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    le, ld = cfg.encoder_layers, cfg.num_layers
    ks = jax.random.split(key, 24)

    enc_blocks = {
        "attn": {
            "wq": L.dense_init(ks[0], (le, d, h * hd), d, dt),
            "wk": L.dense_init(ks[1], (le, d, h * hd), d, dt),
            "wv": L.dense_init(ks[2], (le, d, h * hd), d, dt),
            "wo": L.dense_init(ks[3], (le, h * hd, d), h * hd, dt),
        },
        "mlp": {
            "w_gate": L.dense_init(ks[4], (le, d, cfg.d_ff), d, dt),
            "w_down": L.dense_init(ks[5], (le, cfg.d_ff, d), cfg.d_ff, dt),
        },
        "ln1": _ln_init((le,), d, dt),
        "ln2": _ln_init((le,), d, dt),
    }
    dec_blocks = {
        "self_attn": {
            "wq": L.dense_init(ks[6], (ld, d, h * hd), d, dt),
            "wk": L.dense_init(ks[7], (ld, d, h * hd), d, dt),
            "wv": L.dense_init(ks[8], (ld, d, h * hd), d, dt),
            "wo": L.dense_init(ks[9], (ld, h * hd, d), h * hd, dt),
        },
        "cross_attn": {
            "cross_wq": L.dense_init(ks[10], (ld, d, h * hd), d, dt),
            "cross_wk": L.dense_init(ks[11], (ld, d, h * hd), d, dt),
            "cross_wv": L.dense_init(ks[12], (ld, d, h * hd), d, dt),
            "cross_wo": L.dense_init(ks[13], (ld, h * hd, d), h * hd, dt),
        },
        "mlp": {
            "w_gate": L.dense_init(ks[14], (ld, d, cfg.d_ff), d, dt),
            "w_down": L.dense_init(ks[15], (ld, cfg.d_ff, d), cfg.d_ff, dt),
        },
        "ln1": _ln_init((ld,), d, dt),
        "ln2": _ln_init((ld,), d, dt),
        "ln3": _ln_init((ld,), d, dt),
    }
    return {
        "embed": L.dense_init(ks[16], (cfg.vocab_size, d), d, dt),
        "pos_embed": L.dense_init(ks[17], (MAX_DECODER_POS, d), d, dt),
        "enc_blocks": enc_blocks,
        "enc_ln": _ln_init((), d, dt),
        "dec_blocks": dec_blocks,
        "dec_ln": _ln_init((), d, dt),
    }


def _mha(x, ctx, p, cfg, causal, prefix=""):
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p[prefix + "wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dh->bsh", ctx, p[prefix + "wk"].astype(x.dtype)).reshape(b, -1, h, hd)
    v = jnp.einsum("bsd,dh->bsh", ctx, p[prefix + "wv"].astype(x.dtype)).reshape(b, -1, h, hd)
    o = L.gqa_attention_chunked(q, k, v, causal=causal)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * hd), p[prefix + "wo"].astype(x.dtype))


def _plain_attn(q, k, v):
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pr = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(jnp.float32)).astype(q.dtype)


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames [B, T, D] (stubbed conv-frontend output) -> [B, T, D]."""
    dt = _dt(cfg)
    b, t, d = frames.shape
    x = L.batch_shard(frames.astype(dt) + jnp.asarray(sinusoids(t, d)).astype(dt)[None])

    def block(x, bp):
        h = L.layer_norm(x, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        x = x + _mha(h, h, bp["attn"], cfg, causal=False)
        h = L.layer_norm(x, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        x = x + L.gated_mlp(h, bp["mlp"]["w_gate"], None, bp["mlp"]["w_down"], act="gelu")
        return x, None

    blk = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(blk, x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"], cfg.norm_eps)


def forward(params, tokens: jax.Array, frames: jax.Array, cfg: ModelConfig,
            return_hidden: bool = False) -> jax.Array:
    """Teacher-forced train forward -> logits [B, S, V] (or (hidden, embed)
    when return_hidden; the head is the transposed tied embedding)."""
    enc_out = encode(params, frames, cfg)
    dt = _dt(cfg)
    b, s = tokens.shape
    x = L.batch_shard(
        params["embed"].astype(dt)[tokens] + params["pos_embed"].astype(dt)[:s][None]
    )

    def block(x, bp):
        h = L.layer_norm(x, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        x = x + _mha(h, h, bp["self_attn"], cfg, causal=True)
        h = L.layer_norm(x, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        x = x + _mha(h, enc_out, bp["cross_attn"], cfg, causal=False, prefix="cross_")
        h = L.layer_norm(x, bp["ln3"]["scale"], bp["ln3"]["bias"], cfg.norm_eps)
        x = x + L.gated_mlp(h, bp["mlp"]["w_gate"], None, bp["mlp"]["w_down"], act="gelu")
        return x, None

    blk = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(blk, x, params["dec_blocks"])
    x = L.layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"], cfg.norm_eps)
    if return_hidden:
        return x, params["embed"]
    return L.lm_head(x, params["embed"], transpose=True)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    h, hd, ld = cfg.num_heads, cfg.resolved_head_dim, cfg.num_layers
    dt_ = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    t = cfg.encoder_frames
    return {
        "k": jnp.zeros((ld, batch, max_len, h, hd), dt_),
        "v": jnp.zeros((ld, batch, max_len, h, hd), dt_),
        "cross_k": jnp.zeros((ld, batch, t, h, hd), dt_),
        "cross_v": jnp.zeros((ld, batch, t, h, hd), dt_),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, frames, cfg: ModelConfig, max_len: Optional[int] = None):
    """Encode audio, precompute cross-attention KV, teacher-force the prompt."""
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    max_len = max_len or s
    h, hd = cfg.num_heads, cfg.resolved_head_dim

    def cross_kv(bp):
        k = jnp.einsum("btd,dh->bth", enc_out, bp["cross_attn"]["cross_wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dh->bth", enc_out, bp["cross_attn"]["cross_wv"].astype(enc_out.dtype))
        t = enc_out.shape[1]
        return k.reshape(b, t, h, hd), v.reshape(b, t, h, hd)

    ck, cv = jax.vmap(cross_kv, in_axes=(0,))(params["dec_blocks"])
    logits = forward(params, tokens, frames, cfg)
    cache = init_cache(cfg, b, max_len)
    cache["cross_k"], cache["cross_v"] = ck, cv
    cache["cur"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    dt_ = _dt(cfg)
    b = tokens.shape[0]
    cur = cache["cur"]
    x = params["embed"].astype(dt_)[tokens] + jnp.take(
        params["pos_embed"].astype(dt_), jnp.broadcast_to(cur, (1,)), axis=0
    )[None]
    h_, hd = cfg.num_heads, cfg.resolved_head_dim
    w = cache["k"].shape[2]
    cache_pos = cache["pos"].at[cur % w].set(cur)

    def block(x, bp_kv):
        bp, kc, vc, ck, cv = bp_kv
        h = L.layer_norm(x, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, bp["self_attn"]["wq"].astype(x.dtype)).reshape(b, 1, h_, hd)
        k = jnp.einsum("bsd,dh->bsh", h, bp["self_attn"]["wk"].astype(x.dtype)).reshape(b, 1, h_, hd)
        v = jnp.einsum("bsd,dh->bsh", h, bp["self_attn"]["wv"].astype(x.dtype)).reshape(b, 1, h_, hd)
        slot = cur % w
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        o = L.gqa_attention_decode(q, kc, vc, cache_pos, cur)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, h_ * hd), bp["self_attn"]["wo"].astype(x.dtype))
        # cross attention against precomputed encoder KV
        h2 = L.layer_norm(x, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        q2 = jnp.einsum("bsd,dh->bsh", h2, bp["cross_attn"]["cross_wq"].astype(x.dtype)).reshape(b, 1, h_, hd)
        o2 = _plain_attn(q2, ck, cv)
        x = x + jnp.einsum("bsh,hd->bsd", o2.reshape(b, 1, h_ * hd), bp["cross_attn"]["cross_wo"].astype(x.dtype))
        h3 = L.layer_norm(x, bp["ln3"]["scale"], bp["ln3"]["bias"], cfg.norm_eps)
        x = x + L.gated_mlp(h3, bp["mlp"]["w_gate"], None, bp["mlp"]["w_down"], act="gelu")
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        block, x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    x = L.layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"], cfg.norm_eps)
    logits = L.lm_head(x, params["embed"], transpose=True)
    new_cache = dict(cache, k=ks, v=vs, pos=cache_pos, cur=cur + 1)
    return logits, new_cache
