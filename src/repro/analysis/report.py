"""Findings, severities, and the JSON report the analyzer emits.

Severity policy (what gates CI):

* ``ERROR``   — a violated invariant. Any error makes the report unclean
  and the CLI exit 1. The clean tree must carry zero.
* ``WARNING`` — a hazard the rules cannot prove safe (e.g. a lane dim
  that Mosaic will pad). Recorded, surfaced, does not gate.
* ``INFO``    — measurements worth keeping next to the roofline numbers
  (per-kernel VMEM budgets, sublane padding factors). Never gates.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Dict, List, Optional


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule hit. ``where`` is a kernel/target name for jaxpr rules and
    a ``path:lineno`` for source rules (``lineno`` then set too)."""

    rule: str
    severity: Severity
    where: str
    message: str
    lineno: Optional[int] = None
    data: Optional[Dict] = None  # rule-specific extras (budgets, counts)

    def render(self) -> str:
        loc = f"{self.where}:{self.lineno}" if self.lineno else self.where
        return f"[{self.severity.value}] {self.rule}: {loc}: {self.message}"

    def to_dict(self) -> Dict:
        out = {
            "rule": self.rule,
            "severity": self.severity.value,
            "where": self.where,
            "message": self.message,
        }
        if self.lineno is not None:
            out["lineno"] = self.lineno
        if self.data:
            out["data"] = self.data
        return out


@dataclasses.dataclass
class Report:
    """Aggregated findings over every rule x target/file pair that ran."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    targets_analyzed: List[str] = dataclasses.field(default_factory=list)
    files_analyzed: int = 0
    rules_run: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        self.targets_analyzed.extend(other.targets_analyzed)
        self.files_analyzed += other.files_analyzed
        for r in other.rules_run:
            if r not in self.rules_run:
                self.rules_run.append(r)
        return self

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    @property
    def clean(self) -> bool:
        return not self.errors

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = self.findings if verbose else [
            f for f in self.findings if f.severity is not Severity.INFO
        ]
        for f in shown:
            lines.append(f.render())
        n_err = len(self.errors)
        n_warn = sum(
            1 for f in self.findings if f.severity is Severity.WARNING
        )
        lines.append(
            f"analysis: {len(self.targets_analyzed)} target(s), "
            f"{self.files_analyzed} file(s), {len(self.rules_run)} rule(s) "
            f"-> {n_err} error(s), {n_warn} warning(s)"
        )
        return "\n".join(lines)

    def to_dict(self) -> Dict:
        return {
            "version": 1,
            "clean": self.clean,
            "targets_analyzed": self.targets_analyzed,
            "files_analyzed": self.files_analyzed,
            "rules_run": self.rules_run,
            "summary": self.by_rule(),
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)
