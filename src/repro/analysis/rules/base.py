"""Rule framework: kinds, waivers, registry.

A rule is a small stateless object with a ``name`` (the string findings
carry and waiver comments reference) and one ``check_*`` method per kind.
Source rules honor per-line waiver comments of the form ``# <name>: ok``
(e.g. ``# state-dtype: ok``, ``# host-sync: ok``) so genuine exceptions are
documented at the site they occur.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional

from repro.analysis.report import Finding, Severity


@dataclasses.dataclass
class SourceFile:
    """Parsed source handed to SourceRules: path + text + AST (parsed once
    for the whole battery, with parent links attached)."""

    path: str            # repo-relative (or absolute for temp fixtures)
    text: str
    tree: Optional[ast.AST]
    lines: List[str]

    @classmethod
    def parse(cls, path: str, text: str) -> "SourceFile":
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError:
            tree = None
        else:
            for node in ast.walk(tree):
                for child in ast.iter_child_nodes(node):
                    child._parent = node  # type: ignore[attr-defined]
        return cls(path=path, text=text, tree=tree, lines=text.splitlines())

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Rule:
    name: str = "rule"
    kind: str = "source"  # 'source' | 'kernel' | 'target'

    def waived(self, src: SourceFile, lineno: int) -> bool:
        return f"# {self.name}: ok" in src.line(lineno)

    def finding(self, severity: Severity, where: str, message: str,
                lineno: Optional[int] = None, data=None) -> Finding:
        return Finding(
            rule=self.name, severity=severity, where=where,
            message=message, lineno=lineno, data=data,
        )


class SourceRule(Rule):
    kind = "source"

    def check_file(self, src: SourceFile) -> List[Finding]:
        raise NotImplementedError


class KernelRule(Rule):
    kind = "kernel"

    def check_kernel(self, artifact) -> List[Finding]:
        raise NotImplementedError


class TargetRule(Rule):
    kind = "target"

    def check_target(self, target, closed_jaxpr, artifacts) -> List[Finding]:
        raise NotImplementedError


def _build_registry() -> List[Rule]:
    # imported here (not at module top) so base.py stays import-cycle free
    from repro.analysis.rules.deprecated_alias import DeprecatedAlias
    from repro.analysis.rules.dma_order import DmaHappensBefore, WritebackOrder
    from repro.analysis.rules.host_sync import (
        HostSync, LruStaticKey, TracedCallback,
    )
    from repro.analysis.rules.mosaic_lowering import MosaicGather
    from repro.analysis.rules.state_dtype import StateDtype
    from repro.analysis.rules.vmem_budget import (
        BlockRace, PallasCount, TileGeometry, VmemBudget,
    )

    return [
        # kernel rules
        MosaicGather(),
        DmaHappensBefore(),
        WritebackOrder(),
        TileGeometry(),
        # target rules
        BlockRace(),
        VmemBudget(),
        TracedCallback(),
        PallasCount(),
        # source rules
        StateDtype(),
        HostSync(),
        LruStaticKey(),
        DeprecatedAlias(),
    ]


ALL_RULES: List[Rule] = _build_registry()


def get_rules(names: Optional[List[str]] = None) -> List[Rule]:
    if names is None:
        return list(ALL_RULES)
    by_name = {r.name: r for r in ALL_RULES}
    missing = [n for n in names if n not in by_name]
    if missing:
        raise KeyError(
            f"unknown rule(s) {missing}; known: {sorted(by_name)}"
        )
    return [by_name[n] for n in names]


def source_rules(rules: List[Rule]) -> List[SourceRule]:
    return [r for r in rules if r.kind == "source"]


def kernel_rules(rules: List[Rule]) -> List[KernelRule]:
    return [r for r in rules if r.kind == "kernel"]


def target_rules(rules: List[Rule]) -> List[TargetRule]:
    return [r for r in rules if r.kind == "target"]
