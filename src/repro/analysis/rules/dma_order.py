"""Rules: dma-happens-before + writeback-order.

``dma-happens-before`` is the conformance encoding of the deterministic-
reservation commit discipline (Blelloch et al., PAPERS.md): an async copy
is only *observable* after its wait, so every ``dma_start`` must be paired
with exactly one ``dma_wait`` on the same (semaphore, src, dst) triple in
the same straight-line region — an unwaited copy is a use-before-arrival
race, a double wait deadlocks on silicon even though the interpreter
shrugs.

``writeback-order`` checks the boundary epilogue's aliasing contract
(DESIGN.md §10) on kernels that manually DMA into an input-output-aliased
ANY-memory ref: the LAST write-back must be unconditional and target the
u-block row (the row selected by scalar-prefetch operand 0), so same-block
pairs — which never load the v row — always have their only meaningful row
land last and win.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import KernelRule


class DmaHappensBefore(KernelRule):
    name = "dma-happens-before"

    def check_kernel(self, artifact) -> List[Finding]:
        findings: List[Finding] = []
        where = f"{artifact.target}/{artifact.name}"
        groups: Dict[Tuple, List] = {}
        for ev in artifact.dma_events():
            groups.setdefault((ev.region, ev.key), []).append(ev)

        for (region, _key), evs in sorted(
            groups.items(), key=lambda kv: kv[1][0].position
        ):
            evs.sort(key=lambda e: e.position)
            outstanding = 0
            route = f"{evs[0].src_space}->{evs[0].dst_space}"
            ctx = "cond branch" if region else "kernel body"
            for ev in evs:
                if ev.kind == "start":
                    outstanding += 1
                else:
                    if outstanding == 0:
                        findings.append(self.finding(
                            Severity.ERROR, where,
                            f"dma_wait with no outstanding dma_start "
                            f"({route}, {ctx}): double wait deadlocks on "
                            f"the DMA semaphore",
                            data={"route": route, "position": ev.position},
                        ))
                    else:
                        outstanding -= 1
            if outstanding > 0:
                findings.append(self.finding(
                    Severity.ERROR, where,
                    f"{outstanding} unwaited dma_start ({route}, {ctx}): "
                    f"the copy may still be in flight when its destination "
                    f"is read (use-before-arrival race)",
                    data={"route": route, "unwaited": outstanding},
                ))
        return findings


class WritebackOrder(KernelRule):
    name = "writeback-order"

    def check_kernel(self, artifact) -> List[Finding]:
        where = f"{artifact.target}/{artifact.name}"
        ops = artifact.operands()
        aliased_outputs = {
            ops[dst_kernel_pos].index
            for _in_pos, out_pos in artifact.input_output_aliases
            for dst_kernel_pos in [self._output_operand_index(ops, out_pos)]
            if dst_kernel_pos is not None
            and ops[dst_kernel_pos].space == "any"
        }
        if not aliased_outputs:
            return []  # no manually-DMA'd aliased state: rule not applicable

        invar_by_id = {id(v): i for i, v in enumerate(artifact.jaxpr.invars)}
        writebacks = [
            ev for ev in artifact.dma_events()
            if ev.kind == "start"
            and invar_by_id.get(id(ev.dst_var)) in aliased_outputs
        ]
        if not writebacks:
            return [self.finding(
                Severity.ERROR, where,
                "aliased ANY-memory state ref is never written back: every "
                "grid step's commits are lost",
            )]

        last = max(writebacks, key=lambda e: e.position)
        if last.region:
            return [self.finding(
                Severity.ERROR, where,
                "final state write-back is conditional: same-block pairs "
                "(which skip the v row) can end the step without their u "
                "row landing last (DESIGN.md §10 v-then-u contract)",
                data={"region": repr(last.region)},
            )]
        sources = [artifact.scalar_source(v) for v in last.index_vars]
        if sources and all(s not in (None, 0) for s in sources):
            return [self.finding(
                Severity.ERROR, where,
                f"final unconditional state write-back targets the row of "
                f"scalar-prefetch operand {sources[0]}, not the u block "
                f"(operand 0): v-then-u write-back order is inverted and a "
                f"stale v row wins for same-block pairs",
                data={"row_source": sources[0]},
            )]
        return []

    @staticmethod
    def _output_operand_index(ops, out_pos):
        """Kernel-operand index of grid output ``out_pos``."""
        outs = [op.index for op in ops if op.role == "output"]
        if out_pos < len(outs):
            return outs[out_pos]
        return None
