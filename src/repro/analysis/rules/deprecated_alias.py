"""Rule: deprecated-alias — internal code must not touch deprecated names.

``DistStats.gathered_ints`` was renamed when the state-width refactor made
the gathered payload spec-typed; the old name survives as a property that
fires a ``DeprecationWarning`` for external callers. Internal code
(src/repro, benchmarks/, examples/) reaching for the alias would spam the
warning from inside the library and — worse — keep the dead name looking
alive. The definition site (``core/distributed.py``) and the tests that
pin the deprecation behavior are exempt.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import SourceFile, SourceRule

DEPRECATED_ATTRS = {
    "gathered_ints": "DistStats.gathered_bytes (spec-typed payload)",
}
_EXEMPT_SUFFIX = ("core/distributed.py",)
_EXEMPT_PARTS = ("tests/",)


class DeprecatedAlias(SourceRule):
    name = "deprecated-alias"

    def check_file(self, src: SourceFile) -> List[Finding]:
        path = src.path.replace("\\", "/")
        if src.tree is None:
            return []
        if any(path.endswith(s) for s in _EXEMPT_SUFFIX):
            return []
        if any(p in path for p in _EXEMPT_PARTS):
            return []
        findings: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Attribute):
                continue
            if node.attr not in DEPRECATED_ATTRS:
                continue
            if self.waived(src, node.lineno):
                continue
            findings.append(self.finding(
                Severity.ERROR, src.path,
                f"deprecated alias `.{node.attr}` — use "
                f"{DEPRECATED_ATTRS[node.attr]}; the alias exists only so "
                f"external callers get a DeprecationWarning",
                lineno=node.lineno,
            ))
        return findings
