"""Rule: state-dtype — no hardcoded vertex-state dtypes outside statespec.

This is ``tools/lint_state_dtype.py`` folded into the rule framework (the
CLI there is now a thin shim over this rule; same logic, same waiver).
The state-width refactor (DESIGN.md §12) made ``core/statespec.StateSpec``
the single source of truth for how wide vertex state is at rest, in VMEM,
on the wire, and in counters — a literal ``jnp.int32`` / ``jnp.uint8`` on
a state-array allocation anywhere else silently pins one tier back to a
fixed width.

A violation is an allocator call — ``jnp.zeros``/``ones``/``full``/
``empty``/``*_like``, ``jax.ShapeDtypeStruct``, ``pltpu.VMEM``, or
``.astype`` — whose dtype argument is a literal int32/uint8 AND whose
context names a state-ish value (assignment target or ``.astype`` receiver
matches ``state* / rebuilt / flat / used_*``). Waive a genuine fixed-width
site with ``# state-dtype: ok`` on the same line; ``core/statespec.py``
itself is exempt (it DEFINES the widths).
"""
from __future__ import annotations

import ast
import re
from typing import List

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import SourceFile, SourceRule

DTYPE_LITERALS = {"int32", "uint8"}
DTYPE_MODULES = {"jnp", "np", "numpy", "jax"}
ALLOCATORS = {
    "zeros", "ones", "full", "empty",
    "zeros_like", "ones_like", "full_like", "empty_like",
    "ShapeDtypeStruct", "VMEM", "astype",
}
# Names that denote vertex state (or its aliases through the pipelines):
# the committed state array, the mask-rebuilt state, the flattened
# renumbered state (the bare name ``flat``), and the capacitated per-side
# used counts.
STATEISH = re.compile(
    r"(?:^|_)(?:state|states|rebuilt|used)(?:$|_|[0-9])|^flat[0-9]*$"
)
_EXEMPT_SUFFIX = ("core/statespec.py",)


def _names_in(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr
        elif isinstance(sub, ast.arg):
            yield sub.arg


def _is_dtype_literal(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr in DTYPE_LITERALS
        and isinstance(node.value, ast.Name)
        and node.value.id in DTYPE_MODULES
    )


def _dtype_literal_in_call(call: ast.Call):
    for arg in call.args:
        if _is_dtype_literal(arg):
            return arg.attr
    for kw in call.keywords:
        if kw.arg == "dtype" and _is_dtype_literal(kw.value):
            return kw.value.attr
    return None


def _allocator_name(call: ast.Call):
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _context_names(call: ast.Call):
    """Names the allocation binds to: walk up (via the ``_parent`` links
    SourceFile.parse attached) to the nearest assignment and collect its
    target identifiers — plus, for ``.astype``, the receiver's."""
    names = []
    if isinstance(call.func, ast.Attribute) and call.func.attr == "astype":
        names.extend(_names_in(call.func.value))
    node: ast.AST = call
    while node is not None:
        parent = getattr(node, "_parent", None)
        if isinstance(parent, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (
                parent.targets
                if isinstance(parent, ast.Assign)
                else [parent.target]
            )
            for t in targets:
                names.extend(_names_in(t))
            break
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)):
            break
        node = parent
    return names


class StateDtype(SourceRule):
    name = "state-dtype"

    def check_file(self, src: SourceFile) -> List[Finding]:
        path = src.path.replace("\\", "/")
        if any(path.endswith(s) for s in _EXEMPT_SUFFIX):
            return []
        if src.tree is None:
            return [self.finding(
                Severity.ERROR, src.path, "file does not parse", lineno=0,
            )]
        findings: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            alloc = _allocator_name(node)
            if alloc not in ALLOCATORS:
                continue
            dtype = _dtype_literal_in_call(node)
            if dtype is None:
                continue
            if not any(STATEISH.search(n) for n in _context_names(node)):
                continue
            if self.waived(src, node.lineno):
                continue
            findings.append(self.finding(
                Severity.ERROR, src.path,
                f"state allocation pins dtype {dtype} via {alloc}() — take "
                f"the width from core/statespec.StateSpec (or waive with "
                f"'# {self.name}: ok')",
                lineno=node.lineno,
            ))
        return findings
