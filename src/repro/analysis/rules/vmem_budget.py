"""Rules: vmem-budget, tile-geometry, block-race, pallas-count.

``vmem-budget`` is the static half of the roofline story: per grid step it
sums the double-buffered block bytes, scratch, and a liveness upper bound
on kernel intermediates, records the result (INFO — surfaced in the JSON
report next to the roofline numbers via ``roofline.vmem_step_bytes``), and
errors past the 16 MiB per-core VMEM capacity. For targets the registry
marks rescalable it re-traces at 2x the vertex count with the SAME
window/tile geometry and errors if the footprint moved — the machine-
checked form of the O(window + tile^2), V-independent claim.

``tile-geometry`` checks Mosaic min-tile alignment on every VMEM-resident
block: lane (last) dim must be a multiple of 128 — an ERROR for the 1-byte
state tiers, where misalignment also breaks the (32, 128) min-tile claim —
and sublane padding (e.g. a (2, W) uint8 scratch padded to 32 rows) is
recorded as INFO with its padding factor.

``block-race`` is the grid-order race detector: it evaluates every output
BlockSpec index map over the whole grid in execution order (last dim
innermost) and errors when a block index is revisited non-consecutively —
the revolving-block residency pattern is only sound when all writes to a
block are adjacent grid steps, otherwise the pipeline's write-back of a
later visit clobbers an earlier one (lost update).

``pallas-count`` pins each entry point's kernel census: a refactor that
silently drops (or duplicates) a pallas_call fails instead of passing
vacuously.
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import KernelRule, TargetRule
from repro.analysis.trace import (
    collect_pallas_calls,
    enumerate_grid,
    eval_index_map,
    operand_vmem_bytes,
    peak_live_bytes,
)

VMEM_CAPACITY = 16 * 1024 * 1024   # bytes per TPU core
VMEM_SOFT = 8 * 1024 * 1024        # leave headroom for Mosaic's own use

# Mosaic min sublane count by itemsize (lane is always 128)
_MIN_SUBLANE = {4: 8, 2: 16, 1: 32}


def kernel_step_bytes(artifact) -> dict:
    """Per-grid-step VMEM byte breakdown for one traced kernel."""
    blocks = 0
    scratch = 0
    for op in artifact.operands():
        b = operand_vmem_bytes(op)
        if op.role == "scratch":
            scratch += b
        else:
            blocks += b
    live = peak_live_bytes(artifact.jaxpr)
    return {
        "blocks_bytes": int(blocks),
        "scratch_bytes": int(scratch),
        "live_bytes": int(live),
        "total_bytes": int(blocks + scratch + live),
    }


class TileGeometry(KernelRule):
    name = "tile-geometry"

    def check_kernel(self, artifact) -> List[Finding]:
        findings: List[Finding] = []
        where = f"{artifact.target}/{artifact.name}"
        for op in artifact.operands():
            if op.space != "vmem" or op.dtype is None:
                continue
            shape = op.block_shape or tuple(
                int(s) for s in getattr(op.aval, "shape", ())
            )
            if not shape:
                continue
            itemsize = op.dtype.itemsize
            min_sub = _MIN_SUBLANE.get(itemsize, 8)
            lane = shape[-1]
            if lane % 128 != 0:
                sev = Severity.ERROR if itemsize == 1 else Severity.WARNING
                findings.append(self.finding(
                    sev, where,
                    f"operand {op.index} ({op.role}, {op.dtype}) lane dim "
                    f"{lane} is not a multiple of 128: min tile is "
                    f"({min_sub}, 128)",
                    data={"shape": list(shape), "dtype": str(op.dtype)},
                ))
            if len(shape) >= 2 and shape[-2] % min_sub != 0:
                pad = min_sub / shape[-2] if shape[-2] < min_sub else 1.0
                findings.append(self.finding(
                    Severity.INFO, where,
                    f"operand {op.index} ({op.role}, {op.dtype}) sublane "
                    f"dim {shape[-2]} pads to {min_sub} "
                    f"({pad:.0f}x resident overhead)",
                    data={"shape": list(shape), "min_sublane": min_sub},
                ))
        return findings


class BlockRace(TargetRule):
    name = "block-race"

    def check_target(self, target, closed_jaxpr, artifacts) -> List[Finding]:
        findings: List[Finding] = []
        for art in artifacts:
            where = f"{target.name}/{art.name}"
            pts = enumerate_grid(art.grid)
            if pts is None:
                findings.append(self.finding(
                    Severity.INFO, where,
                    f"grid {art.grid} too large to enumerate — race check "
                    f"skipped",
                ))
                continue
            for op in art.operands():
                if op.role != "output" or op.block_mapping is None:
                    continue
                seq = []
                dynamic = False
                for p in pts:
                    idx = eval_index_map(op.block_mapping, p)
                    if idx is None:
                        dynamic = True
                        break
                    seq.append(idx)
                if dynamic:
                    findings.append(self.finding(
                        Severity.INFO, where,
                        f"operand {op.index} index map is data-dependent — "
                        f"race check skipped (covered by the DMA rules)",
                    ))
                    continue
                revisit = self._nonconsecutive_revisit(seq)
                if revisit is not None:
                    block, first_run_end, again = revisit
                    findings.append(self.finding(
                        Severity.ERROR, where,
                        f"output operand {op.index} writes block {block} at "
                        f"non-consecutive grid steps ({first_run_end} then "
                        f"{again}): the revolving-block pipeline writes the "
                        f"block back between visits and the later visit "
                        f"clobbers the earlier one (lost update)",
                        data={"block": list(block)},
                    ))
        return findings

    @staticmethod
    def _nonconsecutive_revisit(seq):
        last_seen = {}
        for i, block in enumerate(seq):
            if block in last_seen and last_seen[block] != i - 1:
                return block, last_seen[block], i
            last_seen[block] = i
        return None


class VmemBudget(TargetRule):
    name = "vmem-budget"

    def check_target(self, target, closed_jaxpr, artifacts) -> List[Finding]:
        findings: List[Finding] = []
        budgets = {}
        for art in artifacts:
            where = f"{target.name}/{art.name}"
            b = kernel_step_bytes(art)
            budgets[art.name] = b
            total = b["total_bytes"]
            if total > VMEM_CAPACITY:
                sev, verdict = Severity.ERROR, "exceeds 16 MiB VMEM"
            elif total > VMEM_SOFT:
                sev, verdict = Severity.WARNING, "over the 8 MiB soft cap"
            else:
                sev, verdict = Severity.INFO, "within budget"
            findings.append(self.finding(
                sev, where,
                f"per-grid-step VMEM estimate {total / 1024:.0f} KiB "
                f"(blocks {b['blocks_bytes'] / 1024:.0f} + scratch "
                f"{b['scratch_bytes'] / 1024:.0f} + live "
                f"{b['live_bytes'] / 1024:.0f}) — {verdict}"
                + (f"; claim: {target.vmem_claim}" if target.vmem_claim
                   else ""),
                data=b,
            ))

        if target.rescalable and budgets:
            arts2 = collect_pallas_calls(target.trace(2), target.name)
            for art in arts2:
                if art.name not in budgets:
                    continue
                b1 = budgets[art.name]["total_bytes"]
                b2 = kernel_step_bytes(art)["total_bytes"]
                where = f"{target.name}/{art.name}"
                if b2 != b1:
                    findings.append(self.finding(
                        Severity.ERROR, where,
                        f"per-grid-step VMEM moved from {b1} to {b2} bytes "
                        f"when V doubled at fixed window/tile geometry: the "
                        f"O(window + tile^2) V-independence claim is broken",
                        data={"bytes_1x": b1, "bytes_2x": b2},
                    ))
                else:
                    findings.append(self.finding(
                        Severity.INFO, where,
                        f"V-independence verified: {b1} bytes/step at 1x "
                        f"and 2x vertex count",
                        data={"bytes_1x": b1, "bytes_2x": b2},
                    ))
        return findings


class PallasCount(TargetRule):
    name = "pallas-count"

    def check_target(self, target, closed_jaxpr, artifacts) -> List[Finding]:
        n = len(artifacts)
        if n != target.expect_pallas:
            return [self.finding(
                Severity.ERROR, target.name,
                f"expected {target.expect_pallas} pallas_call kernel(s) in "
                f"the trace, found {n} ({[a.name for a in artifacts]}): an "
                f"entry point lost or grew a kernel",
                data={"expected": target.expect_pallas, "found": n},
            )]
        return [self.finding(
            Severity.INFO, target.name,
            f"kernel census: {n} pallas_call(s) "
            f"({[a.name for a in artifacts]})",
        )]
