"""Rule battery for the kernel conformance analyzer.

Three rule kinds (``base.py``):

* ``SourceRule``  — AST/text checks over ``.py`` files (state-dtype,
  host-sync, lru-static-key, deprecated-alias).
* ``KernelRule``  — checks over one traced ``pallas_call`` kernel jaxpr
  (mosaic-gather, dma-happens-before, writeback-order, tile-geometry).
* ``TargetRule``  — checks over a whole traced entry point (block-race,
  vmem-budget, traced-callback, pallas-count).

``ALL_RULES`` is the canonical battery; pass ``--rules`` to the CLI to run
a subset. Each rule's findings carry its name, so a seeded mutation canary
is "caught" precisely when the expected rule reports an ERROR.
"""
from repro.analysis.rules.base import (
    ALL_RULES,
    KernelRule,
    Rule,
    SourceRule,
    TargetRule,
    get_rules,
    kernel_rules,
    source_rules,
    target_rules,
)

__all__ = [
    "ALL_RULES",
    "KernelRule",
    "Rule",
    "SourceRule",
    "TargetRule",
    "get_rules",
    "kernel_rules",
    "source_rules",
    "target_rules",
]
