"""Rule: mosaic-gather — no dynamic gather/scatter inside kernel bodies.

Mosaic cannot lower data-dependent vector gathers/scatters on VMEM values
(and has no sort); the DESIGN.md §10 contract is that every state
gather/scatter in the kernels is a one-hot matmul (``dot_general`` on the
MXU) and block selection happens either through BlockSpec index maps or
explicit DMA of whole rows. This rule walks the kernel jaxpr (including
cond/while sub-jaxprs) and errors on any primitive from the
un-lowerable family. The jnp twins run through XLA and may gather freely —
they are not kernel artifacts, so this rule never sees them.
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import KernelRule
from repro.analysis.trace import iter_eqns

# jaxpr primitives that require data-dependent vector indexing (or are
# otherwise known-unlowerable on the VPU/MXU path our kernels use)
FORBIDDEN = {
    "gather": "data-dependent vector gather",
    "scatter": "data-dependent vector scatter",
    "scatter-update": "data-dependent vector scatter",
    "scatter_update": "data-dependent vector scatter",
    "scatter-add": "data-dependent vector scatter-add",
    "scatter_add": "data-dependent vector scatter-add",
    "sort": "vector sort (no Mosaic lowering)",
    "argsort": "vector sort (no Mosaic lowering)",
}


class MosaicGather(KernelRule):
    name = "mosaic-gather"

    def check_kernel(self, artifact) -> List[Finding]:
        findings: List[Finding] = []
        counts = {}
        for eqn in iter_eqns(artifact.jaxpr):
            prim = eqn.primitive.name
            if prim in FORBIDDEN:
                counts[prim] = counts.get(prim, 0) + 1
        for prim, n in sorted(counts.items()):
            findings.append(self.finding(
                Severity.ERROR,
                f"{artifact.target}/{artifact.name}",
                f"{n} `{prim}` eqn(s) in kernel body: {FORBIDDEN[prim]} "
                f"blocks Mosaic lowering — use the one-hot matmul "
                f"gather/scatter (DESIGN.md §10)",
                data={"primitive": prim, "count": n},
            ))
        return findings
