"""Rules: host-sync, lru-static-key, traced-callback.

``host-sync`` enforces the one-fetch contract: blocking device->host sync
points (``jax.device_get`` / ``.item()``) are only allowed in library code
at documented sites carrying a ``# host-sync: ok`` waiver — everywhere
else they silently serialize the dispatch stream (the distributed driver's
whole DistStats design exists to keep this to ONE fetch per round).
Scoped to ``src/repro``; benchmarks, examples, tools, and tests are host
drivers and fetch freely.

``lru-static-key`` guards the PR 3/PR 5 recompile fixes: an
``lru_cache``'d builder must be keyed on hashable statics only — a
mutable default (list/dict/set) raises at call time, and array-ish
parameter names are a smell that a traced value leaked into the cache key
(every call would then miss and re-trace).

``traced-callback`` (target rule) asserts entry-point jaxprs are free of
host callbacks (``pure_callback`` / ``io_callback`` / ``debug_callback``):
a callback inside a jitted matcher would sync every step.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.report import Finding, Severity
from repro.analysis.rules.base import SourceFile, SourceRule, TargetRule
from repro.analysis.trace import iter_eqns

_ARRAYISH_PARAMS = {"u", "v", "edges", "state", "arr", "array"}


def _in_library(path: str) -> bool:
    p = path.replace("\\", "/")
    return "src/repro/" in p or p.startswith("src/repro")


class HostSync(SourceRule):
    name = "host-sync"

    def check_file(self, src: SourceFile) -> List[Finding]:
        if src.tree is None or not _in_library(src.path):
            return []
        findings: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not isinstance(f, ast.Attribute):
                continue
            if f.attr == "device_get":
                what = "jax.device_get"
            elif f.attr == "item" and not node.args and not node.keywords:
                what = ".item()"
            else:
                continue
            if self.waived(src, node.lineno):
                continue
            findings.append(self.finding(
                Severity.ERROR, src.path,
                f"{what} is a blocking host sync outside the documented "
                f"sites — route it through the one-fetch DistStats path or "
                f"waive with '# {self.name}: ok'",
                lineno=node.lineno,
            ))
        return findings


class LruStaticKey(SourceRule):
    name = "lru-static-key"

    def check_file(self, src: SourceFile) -> List[Finding]:
        if src.tree is None:
            return []
        findings: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(self._is_lru(d) for d in node.decorator_list):
                continue
            if self.waived(src, node.lineno):
                continue
            a = node.args
            defaults = list(a.defaults) + list(a.kw_defaults or [])
            for d in defaults:
                if d is None:
                    continue
                if isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(d, ast.Call)
                    and isinstance(d.func, ast.Name)
                    and d.func.id in ("list", "dict", "set")
                ):
                    findings.append(self.finding(
                        Severity.ERROR, src.path,
                        f"lru_cache'd `{node.name}` has an unhashable "
                        f"(mutable) default — every call raises or misses "
                        f"the cache; key builders on hashable statics only",
                        lineno=node.lineno,
                    ))
            for arg in list(a.args) + list(a.kwonlyargs) + list(
                a.posonlyargs
            ):
                if arg.arg in _ARRAYISH_PARAMS:
                    findings.append(self.finding(
                        Severity.WARNING, src.path,
                        f"lru_cache'd `{node.name}` takes parameter "
                        f"`{arg.arg}` — an array-ish name in a cache key "
                        f"suggests a traced value leaked into the builder "
                        f"signature (constant cache misses / retraces)",
                        lineno=node.lineno,
                    ))
        return findings

    @staticmethod
    def _is_lru(dec: ast.AST) -> bool:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(target, ast.Attribute):
            return target.attr == "lru_cache"
        if isinstance(target, ast.Name):
            return target.id == "lru_cache"
        return False


class TracedCallback(TargetRule):
    name = "traced-callback"

    def check_target(self, target, closed_jaxpr, artifacts) -> List[Finding]:
        jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
        hits = {}
        for eqn in iter_eqns(jaxpr):
            prim = eqn.primitive.name
            if "callback" in prim:
                hits[prim] = hits.get(prim, 0) + 1
        return [
            self.finding(
                Severity.ERROR, target.name,
                f"{n} `{prim}` eqn(s) in the entry-point jaxpr: a host "
                f"callback inside a jitted matcher syncs every dispatch",
                data={"primitive": prim, "count": n},
            )
            for prim, n in sorted(hits.items())
        ]
