"""Kernel conformance analyzer — static Mosaic/DMA/VMEM verification on CPU.

CPU CI only ever executes the Pallas *interpreter* and the xla twins, so
every Mosaic-specific hazard the ROADMAP lists as "verify on silicon" —
dynamic gather/scatter that blocks lowering, DMA/semaphore sequencing in
the block-pair epilogue, ANY-memory state aliasing, the uint8 (32, 128)
min-tile geometry — is invisible until someone gets TPU time. This package
closes that gap statically: every production ``pallas_call`` kernel and
jitted entry point is traced to a jaxpr via abstract eval (no TPU needed)
and a rule battery *proves* per commit that

* no kernel contains dynamic fancy indexing / traced-index gather-scatter
  on VMEM values — only the one-hot matmul gathers of the DESIGN.md §10
  contract (``rules/mosaic_lowering.py``);
* every ``make_async_copy`` start is paired with exactly one wait, nothing
  is double-waited, and the boundary epilogue's v-then-u write-back
  ordering on the aliased ANY-memory state holds — plus a race check over
  the per-grid-step read/write block sets derived from the BlockSpec index
  maps (``rules/dma_order.py``);
* the per-grid-step VMEM footprint fits the budget, is independent of V,
  and the uint8 state blocks honor the (32, 128) min-tile lane geometry
  (``rules/vmem_budget.py``);
* host sync points (``device_get`` / ``.item()``) appear only at
  documented sites and ``lru_cache``'d builders are keyed on hashable
  statics only (``rules/host_sync.py``);
* no literal state dtype escapes ``core/statespec`` (``rules/state_dtype
  .py`` — the former ``tools/lint_state_dtype.py``, now a rule) and no
  internal caller touches the deprecated ``DistStats.gathered_ints``
  alias (``rules/deprecated_alias.py``).

Entry points: ``tools/analyze.py`` (CLI, JSON report, seeded mutation
canaries), or programmatically::

    from repro.analysis import run_analysis
    report = run_analysis()          # all targets + src/repro sources
    assert report.clean, report.render()

See DESIGN.md §14 for what static conformance proves vs. what still needs
silicon.
"""
from repro.analysis.report import Finding, Report, Severity
from repro.analysis.runner import (
    analyze_mutation,
    analyze_sources,
    analyze_targets,
    run_analysis,
)

__all__ = [
    "Finding",
    "Report",
    "Severity",
    "analyze_mutation",
    "analyze_sources",
    "analyze_targets",
    "run_analysis",
]
