"""Jaxpr tracing utilities: pallas_call extraction, DMA events, liveness.

Everything here works on the *abstract* jaxpr jax produces on CPU — no
TPU, no execution. The wrappers normalize the handful of jax internals the
rules need (kernel operand roles, memory spaces, DMA event structure,
BlockSpec index maps) behind small dataclasses so a jax version bump
breaks one file, not every rule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jax_core


# --------------------------------------------------------------------------
# generic jaxpr walking
# --------------------------------------------------------------------------

def _param_jaxprs(eqn) -> Iterator:
    """Yield every sub-jaxpr hiding in an eqn's params (cond branches,
    while/scan bodies, pjit bodies, shard_map bodies, ...)."""
    for v in eqn.params.values():
        for x in (v if isinstance(v, (list, tuple)) else [v]):
            # ClosedJaxpr first: it proxies .eqns, so the order matters
            if hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                yield x.jaxpr
            elif hasattr(x, "eqns"):  # raw Jaxpr
                yield x


def iter_eqns(jaxpr, *, into: Tuple[str, ...] = ()) -> Iterator:
    """Depth-first over every eqn of ``jaxpr`` and all nested sub-jaxprs.

    ``into`` restricts recursion to eqns whose primitive is named there;
    empty means recurse through everything.
    """
    for eqn in jaxpr.eqns:
        yield eqn
        if into and eqn.primitive.name not in into:
            continue
        for sub in _param_jaxprs(eqn):
            yield from iter_eqns(sub, into=into)


def primitive_counts(jaxpr) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
    return out


# --------------------------------------------------------------------------
# kernel (pallas_call) artifacts
# --------------------------------------------------------------------------

def _memory_space(aval) -> str:
    """Normalize a kernel-ref aval's memory space to one of
    ``vmem | smem | any | semaphore | other``. Pallas prints block-mapped
    refs as ``MemRef<None>`` — the default space, which is VMEM."""
    space = getattr(aval, "memory_space", None)
    name = str(space).lower() if space is not None else "none"
    for key in ("semaphore", "smem", "vmem", "any"):
        if key in name:
            return key
    if name in ("none", "memoryspace.none"):
        return "vmem"
    return "other"


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize


@dataclasses.dataclass
class KernelOperand:
    """One kernel invar: its role in the grid spec plus its block mapping
    (``None`` for scalar-prefetch operands, ANY-memory refs without a
    block, and scratch)."""

    index: int            # position among kernel invars
    role: str             # 'index' | 'input' | 'output' | 'scratch'
    space: str            # _memory_space() of the ref aval
    aval: object
    block_mapping: Optional[object] = None  # pallas BlockMapping

    @property
    def block_shape(self) -> Optional[Tuple[int, ...]]:
        if self.block_mapping is None:
            return None
        return tuple(
            int(b) for b in self.block_mapping.block_shape
            if not _is_squeezed(b)
        ) or (1,)

    @property
    def dtype(self):
        return getattr(self.aval, "dtype", None)


def _is_squeezed(dim) -> bool:
    # pallas marks BlockSpec dims mapped with pl.squeezed / None; keep ints
    return not isinstance(dim, (int, np.integer))


@dataclasses.dataclass
class DmaEvent:
    """One ``dma_start`` / ``dma_wait`` eqn, normalized.

    ``key`` identifies the logical copy: the (semaphore var, src ref var,
    dst ref var) triple — a wait matches the start with the same key.
    ``region`` is the straight-line context: () for the kernel body,
    ('cond', i, b) appended per enclosing branch b of the cond at body
    position i. ``position`` orders events by their outermost body index.
    """

    kind: str                      # 'start' | 'wait'
    key: Tuple
    position: int
    region: Tuple
    src_space: str
    dst_space: str
    src_var: object
    dst_var: object
    index_vars: Tuple              # dynamic index operands of the transfer


def _dma_refs(eqn):
    """Split a dma eqn's invars into (src ref, dst ref, sem ref, index
    vars). Layout (jax 0.4.x): [src, *src_idx, dst, *dst_idx, sem, ...] —
    refs are the invars with ref avals, in order src, dst, sem."""
    refs = [v for v in eqn.invars
            if hasattr(getattr(v, "aval", None), "memory_space")
            or "MemRef" in str(getattr(v, "aval", ""))]
    idx = [
        v for v in eqn.invars
        if v not in refs and isinstance(v, jax_core.Var)
    ]
    if len(refs) < 3:  # pragma: no cover - jax layout drift guard
        return None
    return refs[0], refs[1], refs[2], tuple(idx)


def _var_key(v) -> Tuple:
    if isinstance(v, jax_core.Var):
        return ("var", id(v))
    return ("lit", repr(getattr(v, "val", v)))


@dataclasses.dataclass
class KernelArtifact:
    """One traced pallas_call: the kernel jaxpr plus its grid metadata."""

    name: str
    target: str                   # registry target this was found under
    jaxpr: object                 # the kernel Jaxpr
    grid_mapping: object
    input_output_aliases: Tuple
    params: Dict

    # ---- operands -------------------------------------------------------
    def operands(self) -> List[KernelOperand]:
        gm = self.grid_mapping
        n_idx = gm.num_index_operands
        n_in = gm.num_inputs
        n_out = gm.num_outputs
        bms = list(gm.block_mappings)
        ops: List[KernelOperand] = []
        for i, var in enumerate(self.jaxpr.invars):
            if i < n_idx:
                role, bm = "index", None
            elif i < n_idx + n_in:
                role, bm = "input", bms[i - n_idx]
            elif i < n_idx + n_in + n_out:
                role, bm = "output", bms[i - n_idx]
            else:
                role, bm = "scratch", None
            ops.append(KernelOperand(
                index=i, role=role, space=_memory_space(var.aval),
                aval=var.aval, block_mapping=bm,
            ))
        return ops

    @property
    def grid(self) -> Tuple[int, ...]:
        return tuple(int(g) for g in self.grid_mapping.grid)

    # ---- DMA events -----------------------------------------------------
    def dma_events(self) -> List[DmaEvent]:
        events: List[DmaEvent] = []
        self._collect_dma(self.jaxpr, (), events)
        return events

    def _collect_dma(self, jaxpr, region: Tuple, events: List[DmaEvent],
                     base_pos: int = 0, env: Optional[Dict] = None) -> None:
        env = env or {}

        def resolve(v):
            # map sub-jaxpr invars back to the enclosing body's vars so a
            # DMA inside a cond branch still names the kernel's refs
            seen = set()
            while id(v) in env and id(v) not in seen:
                seen.add(id(v))
                v = env[id(v)]
            return v

        for pos, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            if name in ("dma_start", "dma_wait"):
                parts = _dma_refs(eqn)
                if parts is None:
                    continue
                src, dst, sem, idx = parts
                src, dst, sem = resolve(src), resolve(dst), resolve(sem)
                idx = tuple(resolve(v) for v in idx)
                events.append(DmaEvent(
                    kind="start" if name == "dma_start" else "wait",
                    key=(_var_key(sem), _var_key(src), _var_key(dst)),
                    position=base_pos + pos,
                    region=region,
                    src_space=_memory_space(src.aval),
                    dst_space=_memory_space(dst.aval),
                    src_var=src,
                    dst_var=dst,
                    index_vars=idx,
                ))
            elif name == "cond":
                # cond invars = [branch index, *operands]; each branch
                # jaxpr's invars bind the operands positionally
                operands = eqn.invars[1:]
                for b, sub in enumerate(_param_jaxprs(eqn)):
                    sub_env = dict(env)
                    for inner, outer in zip(sub.invars, operands):
                        sub_env[id(inner)] = outer
                    self._collect_dma(
                        sub, region + (("cond", base_pos + pos, b),),
                        events, base_pos + pos, sub_env,
                    )
            elif name in ("while", "scan", "pjit", "custom_jvp_call",
                          "custom_vjp_call", "checkpoint", "remat"):
                for sub in _param_jaxprs(eqn):
                    self._collect_dma(sub, region, events, base_pos + pos,
                                      env)

    # ---- provenance -----------------------------------------------------
    def scalar_source(self, var) -> Optional[int]:
        """If ``var`` is (transitively) a scalar read of an index-operand
        ref (scalar-prefetch SMEM), return that operand's position among
        the index operands; else None. Used to tell the u-block write-back
        from the v-block one in the boundary kernel."""
        n_idx = self.grid_mapping.num_index_operands
        idx_vars = {id(v): i for i, v in
                    enumerate(self.jaxpr.invars[:n_idx])}
        defs = {}
        for eqn in self.jaxpr.eqns:
            for out in eqn.outvars:
                defs[id(out)] = eqn
        seen = set()
        frontier = [var]
        while frontier:
            v = frontier.pop()
            if id(v) in seen or not isinstance(v, jax_core.Var):
                continue
            seen.add(id(v))
            eqn = defs.get(id(v))
            if eqn is None:
                continue
            if eqn.primitive.name == "get":
                ref = eqn.invars[0]
                if id(ref) in idx_vars:
                    return idx_vars[id(ref)]
            frontier.extend(eqn.invars)
        return None


def collect_pallas_calls(closed_jaxpr, target: str) -> List[KernelArtifact]:
    """Every pallas_call eqn reachable from ``closed_jaxpr``, wrapped."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    out: List[KernelArtifact] = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        info = eqn.params.get("name_and_src_info")
        name = getattr(info, "name", None) or eqn.params.get("name", "kernel")
        out.append(KernelArtifact(
            name=str(name),
            target=target,
            jaxpr=eqn.params["jaxpr"],
            grid_mapping=eqn.params["grid_mapping"],
            input_output_aliases=tuple(
                eqn.params.get("input_output_aliases", ())
            ),
            params=eqn.params,
        ))
    return out


# --------------------------------------------------------------------------
# index-map evaluation (per-grid-step read/write sets)
# --------------------------------------------------------------------------

def eval_index_map(block_mapping, grid_point: Sequence[int]):
    """Evaluate a BlockSpec index map at one grid point; returns the block
    coordinate tuple, or None when the map needs runtime data (e.g. reads
    a scalar-prefetch ref) and cannot be enumerated statically."""
    cj = block_mapping.index_map_jaxpr
    n_extra = len(cj.jaxpr.invars) - len(grid_point)
    args = [jnp.int32(g) for g in grid_point]
    for var in cj.jaxpr.invars[len(grid_point):]:
        aval = var.aval
        shape = getattr(aval, "shape", ())
        dtype = getattr(aval, "dtype", jnp.int32)
        args.append(jnp.zeros(shape, dtype))
    if n_extra < 0:
        return None
    try:
        out = jax_core.eval_jaxpr(cj.jaxpr, cj.consts, *args)
    except Exception:
        return None
    return tuple(int(x) for x in out)


def enumerate_grid(grid: Sequence[int], cap: int = 65536):
    """All grid points in execution order (last dim innermost), or None if
    the grid is bigger than ``cap`` steps (registry targets are small)."""
    total = int(np.prod(grid, dtype=np.int64)) if grid else 1
    if total > cap:
        return None
    pts = np.stack(
        np.meshgrid(*[np.arange(g) for g in grid], indexing="ij"), -1
    ).reshape(-1, len(grid)) if grid else np.zeros((1, 0), np.int64)
    return [tuple(int(x) for x in p) for p in pts]


# --------------------------------------------------------------------------
# liveness-based intermediate VMEM estimate
# --------------------------------------------------------------------------

def peak_live_bytes(jaxpr) -> int:
    """Upper-bound the peak bytes of live intermediate values in a kernel
    body: a linear scan with last-use liveness (classic register-pressure
    estimate). Sub-jaxprs (cond/while/pjit) contribute their own peak on
    top of the live set at their call site. Refs are excluded — they are
    counted from block shapes / scratch, not from the value graph."""
    last_use: Dict[int, int] = {}
    eqns = list(jaxpr.eqns)
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if isinstance(v, jax_core.Var):
                last_use[id(v)] = i
    for v in jaxpr.outvars:
        if isinstance(v, jax_core.Var):
            last_use[id(v)] = len(eqns)

    def is_ref(v) -> bool:
        return hasattr(getattr(v, "aval", None), "memory_space") or \
            "MemRef" in str(getattr(v, "aval", ""))

    live: Dict[int, int] = {}
    cur = 0
    peak = 0
    for i, eqn in enumerate(eqns):
        sub_peak = 0
        for sub in _param_jaxprs(eqn):
            sub_peak = max(sub_peak, peak_live_bytes(sub))
        peak = max(peak, cur + sub_peak)
        for v in eqn.outvars:
            if isinstance(v, jax_core.Var) and not is_ref(v):
                b = _aval_bytes(v.aval)
                if b and last_use.get(id(v), -1) > i:
                    live[id(v)] = b
                    cur += b
        peak = max(peak, cur)
        # retire values whose last use was this eqn
        for v in eqn.invars:
            if isinstance(v, jax_core.Var) and last_use.get(id(v)) == i:
                b = live.pop(id(v), 0)
                cur -= b
    return peak


def operand_vmem_bytes(op: KernelOperand) -> int:
    """Resident VMEM bytes one operand costs per grid step. Block-mapped
    refs are double-buffered by the pipeline (x2); VMEM scratch is single;
    ANY-space refs live in HBM (0); SMEM scalars are negligible but
    counted at face value; semaphores are free."""
    if op.space == "semaphore":
        return 0
    if op.space == "any":
        return 0
    if op.role == "scratch":
        return _aval_bytes(op.aval)
    if op.role == "index" or op.space == "smem":
        return _aval_bytes(op.aval)
    bs = op.block_shape
    if bs is None:
        return _aval_bytes(op.aval)
    itemsize = jnp.dtype(op.dtype).itemsize if op.dtype is not None else 1
    return 2 * int(np.prod(bs, dtype=np.int64)) * itemsize
