"""Analyzer driver: sources + traced targets + mutation canaries.

``run_analysis`` is the everything entry point (``tools/analyze.py`` is a
thin CLI over it): AST rules over the given source roots, then jaxpr rules
over every registry target. ``analyze_mutation`` runs the SAME rule battery
over one seeded mutant — the canary is "caught" iff the report carries an
ERROR, which is what the CI job asserts (exit 1, exactly).
"""
from __future__ import annotations

import os
import tempfile
from pathlib import Path
from typing import List, Optional

from repro.analysis import mutations as _mut
from repro.analysis.report import Finding, Report, Severity
from repro.analysis.rules.base import (
    SourceFile,
    get_rules,
    kernel_rules,
    source_rules,
    target_rules,
)
from repro.analysis.targets import get_targets
from repro.analysis.trace import collect_pallas_calls

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def _repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def _iter_py_files(paths: List[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
        elif path.suffix == ".py":
            out.append(path)
    return out


def _rel(path: Path) -> str:
    try:
        return str(path.resolve().relative_to(_repo_root()))
    except ValueError:
        return str(path)


def analyze_sources(paths: List[str], rules=None) -> Report:
    """Run every source rule over the ``.py`` files under ``paths``."""
    rules = get_rules(rules)
    srules = source_rules(rules)
    report = Report(rules_run=[r.name for r in srules])
    for f in _iter_py_files(paths):
        src = SourceFile.parse(_rel(f), f.read_text())
        report.files_analyzed += 1
        for rule in srules:
            report.extend(rule.check_file(src))
    return report


def analyze_targets(names: Optional[List[str]] = None, rules=None) -> Report:
    """Trace every registry target and run the kernel + target rules."""
    rules = get_rules(rules)
    krules = kernel_rules(rules)
    trules = target_rules(rules)
    report = Report(rules_run=[r.name for r in krules + trules])
    for target in get_targets(names):
        try:
            closed = target.trace(1)
        except Exception as exc:  # a target that no longer traces IS a finding
            report.targets_analyzed.append(target.name)
            report.extend([Finding(
                rule="trace", severity=Severity.ERROR, where=target.name,
                message=f"target failed to trace: {type(exc).__name__}: "
                        f"{exc}",
            )])
            continue
        artifacts = collect_pallas_calls(closed, target.name)
        report.targets_analyzed.append(target.name)
        for art in artifacts:
            for rule in krules:
                report.extend(rule.check_kernel(art))
        for rule in trules:
            report.extend(rule.check_target(target, closed, artifacts))
    return report


def run_analysis(paths: Optional[List[str]] = None,
                 targets: Optional[List[str]] = None,
                 rules=None) -> Report:
    """Sources + targets in one report (the CI surface)."""
    if paths is None:
        paths = [str(_repo_root() / "src" / "repro")]
    report = analyze_sources(paths, rules)
    return report.merge(analyze_targets(targets, rules))


def analyze_mutation(name: str, rules=None) -> Report:
    """Run the battery over one seeded mutant (see ``mutations.py``).

    Kernel mutants re-trace the boundary grid spec with the mutated body
    and run the kernel rules; the source mutant is written to a temp file
    and linted. A clean report here means the analyzer LOST ITS TEETH.
    """
    if name in _mut.KERNEL_MUTATIONS:
        closed = _mut.trace_kernel_mutation(name)
        artifacts = collect_pallas_calls(closed, f"mutation:{name}")
        krules = kernel_rules(get_rules(rules))
        report = Report(
            rules_run=[r.name for r in krules],
            targets_analyzed=[f"mutation:{name}"],
        )
        for art in artifacts:
            for rule in krules:
                report.extend(rule.check_kernel(art))
        return report
    if name in _mut.SOURCE_MUTATIONS:
        fd, tmp = tempfile.mkstemp(suffix=f"_{name}.py", text=True)
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(_mut.SOURCE_MUTATIONS[name])
            return analyze_sources([tmp], rules)
        finally:
            os.unlink(tmp)
    raise KeyError(
        f"unknown mutation {name!r}; known: {_mut.MUTATION_NAMES}"
    )
