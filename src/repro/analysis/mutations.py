"""Seeded mutants that prove the analyzer has teeth.

Each mutant is a faithful copy of ``skipper_boundary_kernel`` (the kernel
with the richest invariant surface: manual DMA, ANY-memory aliasing,
ordered write-back) with exactly ONE conformance invariant broken:

* ``dropped_dma_wait``      — the u-row load's ``wait()`` is gone: the tile
  body reads ``pair_ref`` while the copy may still be in flight.
* ``swapped_writeback``     — write-back order inverted (u row first,
  v row last-and-conditional): same-block pairs now let a stale v row win,
  breaking the DESIGN.md §10 aliasing contract.
* ``dynamic_gather``        — the one-hot matmul gather replaced by traced
  fancy indexing on the VMEM scratch (the exact pattern that blocks Mosaic
  lowering and that PR 5 removed).
* ``hardcoded_state_dtype`` — a SOURCE fixture (string, materialized to a
  temp file at analysis time — it cannot live as a real module here or the
  tree-wide state-dtype rule would flag the repo itself) that allocates a
  state buffer with a literal dtype instead of ``StateSpec``.

``tests/test_analysis.py`` and the CI canary assert each mutant yields a
rule-named ERROR finding; a mutant that analyzes clean means the analyzer
lost its teeth and fails the build.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import engine
from repro.core.statespec import DEFAULT, StateSpec
from repro.kernels.skipper_match.kernel import _match_tile, _one_hot

_TILE = 256
_WINDOW = 256
_NUM_WINDOWS = 4


def _mutant_dropped_dma_wait(
    blk_u_ref, blk_v_ref, u_ref, v_ref, state_in_ref, state_ref,
    matched_ref, conflicts_ref, pair_ref, sem_u, sem_v,
    *, vector_rounds: int, window: int, fallback: bool, spec: StateSpec,
):
    """Boundary kernel minus the u-row load wait (use-before-arrival race)."""
    i = pl.program_id(0)
    bu = blk_u_ref[i]
    bv = blk_v_ref[i]

    cp_u = pltpu.make_async_copy(state_ref.at[bu], pair_ref.at[0], sem_u)
    cp_u.start()
    # MUTATION: cp_u.wait() dropped — pair_ref[0] may not have landed.

    @pl.when(bv != bu)
    def _load_v():
        cp = pltpu.make_async_copy(state_ref.at[bv], pair_ref.at[1], sem_v)
        cp.start()
        cp.wait()

    def _set_pair(value):
        pair_ref[...] = value.reshape(2, window)

    cell = engine.StateCell(
        get=lambda: pair_ref[...].reshape(2 * window), set=_set_pair
    )
    matched, conflicts = _match_tile(
        u_ref[0, :], v_ref[0, :], cell,
        vector_rounds=vector_rounds, window=2 * window, fallback=fallback,
    )
    matched_ref[0, :] = matched.astype(spec.counter_dtype)
    conflicts_ref[0, :] = conflicts.astype(spec.counter_dtype)

    @pl.when(bv != bu)
    def _store_v():
        cp = pltpu.make_async_copy(pair_ref.at[1], state_ref.at[bv], sem_v)
        cp.start()
        cp.wait()

    cp_u2 = pltpu.make_async_copy(pair_ref.at[0], state_ref.at[bu], sem_u)
    cp_u2.start()
    cp_u2.wait()


def _mutant_swapped_writeback(
    blk_u_ref, blk_v_ref, u_ref, v_ref, state_in_ref, state_ref,
    matched_ref, conflicts_ref, pair_ref, sem_u, sem_v,
    *, vector_rounds: int, window: int, fallback: bool, spec: StateSpec,
):
    """Boundary kernel with the write-back order inverted (u first, v last)."""
    i = pl.program_id(0)
    bu = blk_u_ref[i]
    bv = blk_v_ref[i]

    cp_u = pltpu.make_async_copy(state_ref.at[bu], pair_ref.at[0], sem_u)
    cp_u.start()
    cp_u.wait()

    @pl.when(bv != bu)
    def _load_v():
        cp = pltpu.make_async_copy(state_ref.at[bv], pair_ref.at[1], sem_v)
        cp.start()
        cp.wait()

    def _set_pair(value):
        pair_ref[...] = value.reshape(2, window)

    cell = engine.StateCell(
        get=lambda: pair_ref[...].reshape(2 * window), set=_set_pair
    )
    matched, conflicts = _match_tile(
        u_ref[0, :], v_ref[0, :], cell,
        vector_rounds=vector_rounds, window=2 * window, fallback=fallback,
    )
    matched_ref[0, :] = matched.astype(spec.counter_dtype)
    conflicts_ref[0, :] = conflicts.astype(spec.counter_dtype)

    # MUTATION: u row stored FIRST, v row last (and conditionally) — a
    # same-block pair's only meaningful row no longer wins unconditionally.
    cp_u2 = pltpu.make_async_copy(pair_ref.at[0], state_ref.at[bu], sem_u)
    cp_u2.start()
    cp_u2.wait()

    @pl.when(bv != bu)
    def _store_v():
        cp = pltpu.make_async_copy(pair_ref.at[1], state_ref.at[bv], sem_v)
        cp.start()
        cp.wait()


def _mutant_dynamic_gather(
    blk_u_ref, blk_v_ref, u_ref, v_ref, state_in_ref, state_ref,
    matched_ref, conflicts_ref, pair_ref, sem_u, sem_v,
    *, vector_rounds: int, window: int, fallback: bool, spec: StateSpec,
):
    """Boundary kernel with the one-hot MXU gather replaced by traced fancy
    indexing on the VMEM scratch — the pre-PR-5 pattern Mosaic cannot lower."""
    i = pl.program_id(0)
    bu = blk_u_ref[i]
    bv = blk_v_ref[i]

    cp_u = pltpu.make_async_copy(state_ref.at[bu], pair_ref.at[0], sem_u)
    cp_u.start()
    cp_u.wait()

    @pl.when(bv != bu)
    def _load_v():
        cp = pltpu.make_async_copy(state_ref.at[bv], pair_ref.at[1], sem_v)
        cp.start()
        cp.wait()

    u = u_ref[0, :]
    v = v_ref[0, :]
    valid = (u >= 0) & (u != v)
    flat = pair_ref[...].reshape(2 * window)
    # MUTATION: data-dependent vector gather (jaxpr `gather` with a traced
    # index operand) instead of one_hot(u) @ state.
    su = flat[jnp.where(valid, u, 0)]
    sv = flat[jnp.where(valid, v, 0)]
    matched = valid & (su == 0) & (sv == 0)

    hu = _one_hot(jnp.where(matched, u, -1), 2 * window)
    hv = _one_hot(jnp.where(matched, v, -1), 2 * window)
    ci = matched.astype(jnp.int32)
    hit = (ci @ hu) + (ci @ hv)
    pair_ref[...] = jnp.where(
        hit > 0, engine.MCHD, flat
    ).astype(spec.vmem_dtype).reshape(2, window)

    matched_ref[0, :] = matched.astype(spec.counter_dtype)
    conflicts_ref[0, :] = jnp.zeros_like(u).astype(spec.counter_dtype)

    @pl.when(bv != bu)
    def _store_v():
        cp = pltpu.make_async_copy(pair_ref.at[1], state_ref.at[bv], sem_v)
        cp.start()
        cp.wait()

    cp_u2 = pltpu.make_async_copy(pair_ref.at[0], state_ref.at[bu], sem_u)
    cp_u2.start()
    cp_u2.wait()


# Source-rule fixture: a literal state dtype outside core/statespec. Kept as
# a string so the repo-wide state-dtype scan stays clean; the runner writes
# it to a temp file and lints that.
HARDCODED_STATE_DTYPE_SRC = '''\
"""Mutation fixture: hard-coded state dtype (must trip the state-dtype rule)."""
import jax.numpy as jnp


def make_state(num_vertices):
    state = jnp.zeros((num_vertices,), dtype=jnp.int32)
    return state
'''


def _build_mutant_call(kernel_fn, spec: StateSpec = DEFAULT):
    """Wrap a mutant kernel in the production boundary grid spec (verbatim
    copy of ``build_boundary_matcher``'s spec at the canonical shapes)."""
    num_tiles, tile_size = 2, _TILE
    num_windows, window = _NUM_WINDOWS, _WINDOW
    spec.validate_rounds(1)
    kernel = functools.partial(
        kernel_fn, vector_rounds=1, window=window, fallback=True, spec=spec
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, window), spec.vmem_dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_windows, window), spec.vmem_dtype),
            jax.ShapeDtypeStruct((num_tiles, tile_size), spec.counter_dtype),
            jax.ShapeDtypeStruct((num_tiles, tile_size), spec.counter_dtype),
        ],
        input_output_aliases={4: 0},
        interpret=True,
    )
    blk = jax.ShapeDtypeStruct((num_tiles,), jnp.int32)
    uv = jax.ShapeDtypeStruct((num_tiles, tile_size), jnp.int32)
    st = jax.ShapeDtypeStruct((num_windows, window), spec.vmem_dtype)
    return jax.make_jaxpr(call)(blk, blk, uv, uv, st)


KERNEL_MUTATIONS = {
    "dropped_dma_wait": _mutant_dropped_dma_wait,
    "swapped_writeback": _mutant_swapped_writeback,
    "dynamic_gather": _mutant_dynamic_gather,
}

SOURCE_MUTATIONS = {
    "hardcoded_state_dtype": HARDCODED_STATE_DTYPE_SRC,
}

MUTATION_NAMES = sorted(KERNEL_MUTATIONS) + sorted(SOURCE_MUTATIONS)


def trace_kernel_mutation(name: str, spec: StateSpec = DEFAULT):
    return _build_mutant_call(KERNEL_MUTATIONS[name], spec)
