"""Production trace targets: every pallas kernel + jitted entry point.

Each target builds a ClosedJaxpr for one production surface via abstract
eval at small canonical shapes (CPU-only — nothing executes). The shapes
are chosen to exercise the real grid structure (multi-window grids, a
non-empty block-pair boundary tier, cross- and same-block pairs) while
keeping tracing fast enough for CI.

Targets that declare ``rescale`` can be re-traced with the vertex count
scaled by an integer factor at the SAME window/tile geometry — that is
what lets ``rules/vmem_budget.py`` *prove* the per-grid-step VMEM
footprint is independent of V (the O(window + tile^2) claim of DESIGN.md
§10) instead of asserting it in prose.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.statespec import DEFAULT, StateSpec

# canonical geometry: small but structurally faithful
_TILE = 256
_WINDOW = 256
_NUM_WINDOWS = 4
_TILES_PER_WINDOW = 2
_SEED = 0


@dataclasses.dataclass(frozen=True)
class Target:
    """One analyzable surface.

    ``build(scale)`` traces at ``scale``x the canonical vertex count
    (same window/tile geometry). ``expect_pallas`` is the number of
    pallas_call kernels the trace must contain — a structural conformance
    check: if a refactor silently drops a kernel from an entry point, the
    analyzer fails rather than passing vacuously.
    """

    name: str
    build: Callable[[int], object]     # scale -> ClosedJaxpr
    expect_pallas: int = 0
    rescalable: bool = False
    vmem_claim: str = ""

    def trace(self, scale: int = 1):
        return self.build(scale)


def _spec() -> StateSpec:
    return DEFAULT


# --------------------------------------------------------------------------
# kernel targets
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _trace_window_kernel(scale: int):
    from repro.kernels.skipper_match.kernel import build_window_matcher

    spec = _spec()
    call = build_window_matcher(2, _TILE, _WINDOW, 1, True, True, spec)
    uv = jax.ShapeDtypeStruct((2 * _TILE,), jnp.int32)
    st = jax.ShapeDtypeStruct((_WINDOW,), spec.vmem_dtype)
    return jax.make_jaxpr(call)(uv, uv, st)


@functools.lru_cache(maxsize=None)
def _trace_pipeline_kernel(scale: int):
    from repro.kernels.skipper_match.kernel import build_pipeline_matcher

    spec = _spec()
    nw = _NUM_WINDOWS * scale
    call = build_pipeline_matcher(
        nw, _TILES_PER_WINDOW, _TILE, _WINDOW, 1, True, True, spec
    )
    uv = jax.ShapeDtypeStruct((nw, _TILES_PER_WINDOW * _TILE), jnp.int32)
    st = jax.ShapeDtypeStruct((nw, _WINDOW), spec.vmem_dtype)
    return jax.make_jaxpr(call)(uv, uv, st)


@functools.lru_cache(maxsize=None)
def _trace_boundary_kernel(scale: int):
    from repro.kernels.skipper_match.kernel import build_boundary_matcher

    spec = _spec()
    nw = _NUM_WINDOWS * scale
    call = build_boundary_matcher(2, _TILE, nw, _WINDOW, 1, True, True, spec)
    blk = jax.ShapeDtypeStruct((2,), jnp.int32)
    uv = jax.ShapeDtypeStruct((2, _TILE), jnp.int32)
    st = jax.ShapeDtypeStruct((nw, _WINDOW), spec.vmem_dtype)
    return jax.make_jaxpr(call)(blk, blk, uv, uv, st)


@functools.lru_cache(maxsize=None)
def _trace_flash_attention(scale: int):
    from repro.kernels.flash_attention.kernel import build_flash_attention

    call = build_flash_attention(
        batch=1, num_q_heads=2, num_kv_heads=1, seq_len=256,
        head_dim=128, block_q=128, block_k=128, interpret=True,
    )
    q = jax.ShapeDtypeStruct((1, 2, 256, 128), jnp.bfloat16)
    kv = jax.ShapeDtypeStruct((1, 1, 256, 128), jnp.bfloat16)
    return jax.make_jaxpr(call)(q, kv, kv)


# --------------------------------------------------------------------------
# entry-point targets
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _small_graph(scale: int = 1):
    from repro.graphs.types import EdgeList

    rng = np.random.default_rng(_SEED)
    n = _NUM_WINDOWS * _WINDOW * scale
    m = 4 * n
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    return EdgeList(
        u=jnp.asarray(u), v=jnp.asarray(v), num_vertices=n
    )


@functools.lru_cache(maxsize=None)
def _small_schedule(scale: int = 1):
    from repro.graphs.windows import build_window_schedule

    return build_window_schedule(_small_graph(scale), _WINDOW, _TILE, True)


def _trace_skipper_match(backend: str, scale: int):
    from repro.kernels.skipper_match import ops

    spec = _spec()
    sched = _small_schedule(scale)
    fn = ops._build_pipeline(
        sched.num_windows, sched.num_rows, sched.tiles_per_window,
        sched.tile_size, sched.window, sched.num_boundary_padded,
        sched.num_edges, sched.num_vertices, 1, True, backend, "auto",
        None, spec,
    )
    sd = lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
    perm = jax.ShapeDtypeStruct((sched.num_vertices,), jnp.int32)
    return jax.make_jaxpr(fn)(
        sd(sched.u_tiles), sd(sched.v_tiles), sd(sched.stream_src),
        sd(sched.boundary_blk_u), sd(sched.boundary_blk_v),
        sd(sched.boundary_ulocal), sd(sched.boundary_vlocal),
        sd(sched.window_ids), perm,
    )


@functools.lru_cache(maxsize=None)
def _trace_skipper_match_pallas(scale: int):
    return _trace_skipper_match("pallas", scale)


@functools.lru_cache(maxsize=None)
def _trace_skipper_match_xla(scale: int):
    return _trace_skipper_match("xla", scale)


@functools.lru_cache(maxsize=None)
def _trace_distributed_sharded(scale: int):
    from repro import compat
    from repro.core import distributed
    from repro.graphs.partition import locality_device_schedule

    spec = _spec()
    ds = locality_device_schedule(
        _small_graph(scale), 1, 512, window=_WINDOW, tile_size=_TILE,
        reorder="none",
    )
    sched = ds.schedule
    mesh = compat.make_mesh((1,), ("data",))
    run = distributed._compiled_sharded(
        mesh, "data", 1, sched.window, sched.tiles_per_window,
        sched.tile_size, sched.num_rows, sched.num_windows,
        sched.num_boundary_padded, 1, 4, "xla", True, None, spec,
    )
    sd = lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
    return jax.make_jaxpr(run)(
        sd(ds.u_rows), sd(ds.v_rows), sd(ds.row_slot),
        sd(ds.boundary_ub), sd(ds.boundary_vb), sd(ds.boundary_ib),
        sd(sched.window_ids), sd(sched.boundary_u), sd(sched.boundary_v),
    )


@functools.lru_cache(maxsize=None)
def _trace_distributed_dispersed(scale: int):
    from repro import compat
    from repro.core import distributed
    from repro.graphs.partition import dispersed_blocks

    spec = _spec()
    g = _small_graph(scale)
    ub, vb = dispersed_blocks(g.canonical(), 1, 512)
    num_rounds = ub.shape[1]
    mesh = compat.make_mesh((1,), ("data",))
    run = distributed._compiled_dispersed(
        mesh, "data", 1, g.num_vertices, num_rounds * 512, 1, _TILE, 4,
        None, spec,
    )
    sd = lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
    ib = jax.ShapeDtypeStruct(np.asarray(ub).shape, jnp.int32)
    return jax.make_jaxpr(run)(sd(ub), sd(vb), ib)


@functools.lru_cache(maxsize=None)
def _trace_bmatch_assign(scale: int):
    from repro.core.bipartite import bmatch_assign

    fn = functools.partial(
        bmatch_assign, num_tokens=512, num_experts=8, token_budget=2,
        expert_capacity=128, tile_size=512,
    )
    tok = jax.ShapeDtypeStruct((1024,), jnp.int32)
    exp = jax.ShapeDtypeStruct((1024,), jnp.int32)
    return jax.make_jaxpr(fn)(tok, exp)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

TARGETS: Dict[str, Target] = {
    t.name: t for t in [
        Target(
            name="window_kernel",
            build=_trace_window_kernel,
            expect_pallas=1,
            vmem_claim="O(window + tile^2): single-window debug surface",
        ),
        Target(
            name="pipeline_kernel",
            build=_trace_pipeline_kernel,
            expect_pallas=1,
            rescalable=True,
            vmem_claim="O(window + tile^2), independent of V "
                       "(state block revolves per window)",
        ),
        Target(
            name="boundary_kernel",
            build=_trace_boundary_kernel,
            expect_pallas=1,
            rescalable=True,
            vmem_claim="O(window + tile^2), independent of V "
                       "(DESIGN.md §10: (2, W) pair scratch, ANY state)",
        ),
        Target(
            name="flash_attention",
            build=_trace_flash_attention,
            expect_pallas=1,
            vmem_claim="O(block_q * d + S * d) per (batch, head) step",
        ),
        Target(
            name="skipper_match_pallas",
            build=_trace_skipper_match_pallas,
            expect_pallas=2,  # pipeline sweep + boundary epilogue
        ),
        Target(
            name="skipper_match_xla",
            build=_trace_skipper_match_xla,
            expect_pallas=0,  # the jnp twin must stay pallas-free
        ),
        Target(
            name="distributed_sharded",
            build=_trace_distributed_sharded,
            expect_pallas=0,  # xla backend on CPU CI
        ),
        Target(
            name="distributed_dispersed",
            build=_trace_distributed_dispersed,
            expect_pallas=0,
        ),
        Target(
            name="bmatch_assign",
            build=_trace_bmatch_assign,
            expect_pallas=0,
        ),
    ]
}


def get_targets(names: Optional[List[str]] = None) -> List[Target]:
    if names is None:
        return list(TARGETS.values())
    missing = [n for n in names if n not in TARGETS]
    if missing:
        raise KeyError(
            f"unknown analysis target(s) {missing}; "
            f"known: {sorted(TARGETS)}"
        )
    return [TARGETS[n] for n in names]
