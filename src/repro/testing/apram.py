"""Step-level APRAM model of the single-pass reservation protocol.

This is the ground truth every matcher in the repo is conformance-checked
against (DESIGN.md §13). The model is deliberately tiny and dumb — plain
numpy, one python loop, no vectorization tricks — so that it can be read
against the paper's Alg. 1 line by line and trusted.

**The model.** ``n`` single-byte vertex cells, each ACC(0) or MCHD(2)
(RSVD(1) exists only *inside* an event — the paper's merged reserve+commit
makes the reservation window atomic, which is exactly the property being
modeled). One *event* per stream edge. A schedule is a permutation of the
event indices — the APRAM adversary's only power is choosing the order in
which the atomic events hit the cells. Each event, atomically:

    if both endpoint cells are ACC:  write MCHD to both; the edge MATCHES
    else:                            the edge is DEAD

Invalid stream slots (self-loops, negative ids, out-of-range endpoints —
the same validity predicate as ``core/validate.check_matching``) are
skipped events: decided, never matched, never touching a cell.

**Per-step invariants** (checked after every event unless
``check_every_step=False``):

* *state domain* — every cell is ACC or MCHD; a reservation never leaks.
* *no double-match* — a commit finds both cells ACC and unowned; matched
  edges are endpoint-disjoint by construction, and the model verifies it
  via the per-vertex ``owner`` map instead of assuming it.
* *monotone commit* — MCHD cells never revert; decisions never flip.
* *decision soundness* — a DEAD valid edge has an MCHD endpoint at the
  moment of death, and that endpoint is owned by a *matched* edge (this is
  the paper's "an edge is dead only if one of its endpoints is already
  matched"; the ownership half is what catches zombie reservations).

**Quiescence checks** (always, via :meth:`ApramResult.check_quiescent`):
every valid edge decided; validity + maximality of the matched mask via
``core/validate.check_matching``; and the final cell array must equal the
state rebuilt from the mask alone (no cell is MCHD without a committed
edge owning it, and vice versa).

**Mutations.** ``mutation=`` selects a seeded protocol bug — a model of a
*wrong* implementation of the merged step — which the invariant checks
must catch on contended schedules (the fuzz CLI's canary and the mutation
tests rely on this):

* ``commit_before_reserve`` — write MCHD to the first endpoint before the
  partner cell is checked; on conflict the half-commit is never rolled
  back (a zombie vertex: MCHD, owned by a dead edge).
* ``skip_partner_check`` — decide on the first endpoint alone; commits
  can double-book the partner vertex (validity violation).
* ``leak_reservation`` — on conflict, leave the first endpoint RSVD
  instead of rolling back (state-domain violation).
* ``drop_commit`` — report the edge matched but never write the cells
  (mask/state divergence; later neighbors double-match).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

ACC = 0
RSVD = 1
MCHD = 2

#: Protocol mutations the harness must catch (name -> doc). The values are
#: human-readable one-liners; the dispatch lives in :func:`_event`.
MUTATIONS = {
    "commit_before_reserve": "MCHD the first endpoint before checking the "
    "partner; never roll back (zombie vertex on conflict)",
    "skip_partner_check": "decide on the first endpoint alone; the partner "
    "cell can be double-booked",
    "leak_reservation": "leave RSVD in the first endpoint on conflict "
    "instead of rolling back",
    "drop_commit": "report matched without writing either cell",
}


class ApramViolation(AssertionError):
    """A per-step or quiescence invariant of the APRAM model failed.

    Carries ``step`` (position in the schedule), ``event`` (stream edge
    index) and ``invariant`` (short name) for machine consumption by the
    fuzzer's shrinker.
    """

    def __init__(self, message: str, *, step: int = -1, event: int = -1,
                 invariant: str = ""):
        super().__init__(message)
        self.step = step
        self.event = event
        self.invariant = invariant


@dataclasses.dataclass
class ApramResult:
    """Outcome of one scheduled APRAM execution.

    ``matched``/``decided`` are aligned with the STREAM order (not the
    schedule order); ``owner[w]`` is the stream index of the edge that
    committed vertex ``w`` (-1 while ACC); ``violations`` is non-empty only
    when the run was executed with ``strict=False``.
    """

    u: np.ndarray              # int64[m] canonical endpoints (u <= v)
    v: np.ndarray
    num_vertices: int
    schedule: np.ndarray       # int64[m] event order (a permutation)
    matched: np.ndarray        # bool[m]
    decided: np.ndarray        # bool[m]
    state: np.ndarray          # uint8[n]
    owner: np.ndarray          # int64[n]
    violations: list

    @property
    def num_matches(self) -> int:
        return int(self.matched.sum())

    def matching_key(self) -> bytes:
        """Hashable identity of the produced matching (for counting the
        distinct outcomes a schedule family can reach)."""
        return np.packbits(self.matched).tobytes()

    def check_quiescent(self) -> dict:
        """Quiescence checks; raises :class:`ApramViolation` on failure.

        Returns the ``core/validate.check_matching`` dict (host ints) so
        callers can also look at match counts.
        """
        valid = _valid_mask(self.u, self.v, self.num_vertices)
        undecided = valid & ~self.decided
        if undecided.any():
            k = int(np.flatnonzero(undecided)[0])
            raise ApramViolation(
                f"quiescence: valid edge ({self.u[k]}, {self.v[k]}) at "
                f"stream index {k} was never decided (not a single pass)",
                event=k, invariant="single_pass",
            )
        # cells must be exactly the mask-rebuilt state: MCHD iff covered
        # the model's cells are the paper's literal single bytes, not a
        # StateSpec tier — fixed width is the point
        rebuilt = np.zeros(self.num_vertices, np.uint8)  # state-dtype: ok
        sel = self.matched & valid
        rebuilt[self.u[sel]] = MCHD
        rebuilt[self.v[sel]] = MCHD
        if not np.array_equal(rebuilt, self.state):
            w = int(np.flatnonzero(rebuilt != self.state)[0])
            raise ApramViolation(
                f"quiescence: cell {w} is {int(self.state[w])} but the "
                f"matched mask implies {int(rebuilt[w])} (state/mask "
                "divergence)",
                invariant="state_mask_agreement",
            )
        out = _check_matching_host(
            self.u, self.v, self.num_vertices, self.matched
        )
        if not out["valid"]:
            raise ApramViolation(
                "quiescence: matched mask has endpoint collisions",
                invariant="validity",
            )
        if not out["maximal"]:
            raise ApramViolation(
                "quiescence: matched mask is not maximal",
                invariant="maximality",
            )
        return out


def _valid_mask(u: np.ndarray, v: np.ndarray, n: int) -> np.ndarray:
    """The exact validity predicate of ``core/validate.check_matching``:
    canonical u <= v, so ``v < n`` bounds both endpoints."""
    return (u != v) & (u >= 0) & (v < n)


def _check_matching_host(u, v, n, mask) -> dict:
    """Validity + maximality via ``core/validate.check_matching`` — the
    same code path the production matchers are validated with, converted
    to host booleans. Imported lazily so the hot model loop stays
    numpy-only until quiescence."""
    import jax
    import jax.numpy as jnp

    from repro.core.validate import check_matching
    from repro.graphs.types import EdgeList

    e = EdgeList(
        jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32), int(n)
    )
    out = check_matching(e, jnp.asarray(mask))
    host = jax.device_get(out)  # host-sync: ok (test oracle)
    return {k: (bool(x) if x.dtype == np.bool_ else int(x))
            for k, x in host.items()}


def _canonical(u, v) -> Tuple[np.ndarray, np.ndarray]:
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    return np.minimum(u, v), np.maximum(u, v)


def run_schedule(
    edges,
    schedule: Sequence[int],
    *,
    mutation: Optional[str] = None,
    strict: bool = True,
    check_every_step: bool = True,
    check_quiescence: bool = True,
) -> ApramResult:
    """Execute the APRAM model under one schedule.

    Args:
        edges: an ``EdgeList`` or ``(u, v, num_vertices)`` tuple; endpoint
            order per edge is irrelevant (canonicalized like the matchers).
        schedule: permutation of ``range(m)`` — the event order. Checked;
            a non-permutation is a harness bug, not a protocol outcome.
        mutation: ``None`` (the paper's protocol) or a key of
            :data:`MUTATIONS`.
        strict: raise :class:`ApramViolation` at the first violated
            invariant (default). ``False`` records violations in
            ``result.violations`` and keeps going — the mutation tests use
            it to observe *what* a bug breaks.
        check_every_step: run the O(n) per-step sweeps (domain,
            monotonicity) after every event. The O(1) event-local checks
            (double-match, decision soundness) always run.
        check_quiescence: run :meth:`ApramResult.check_quiescent` at the
            end (strict mode only raises; non-strict records).

    Returns:
        :class:`ApramResult`.
    """
    if hasattr(edges, "num_vertices"):
        u, v = _canonical(np.asarray(edges.u), np.asarray(edges.v))
        n = int(edges.num_vertices)
    else:
        eu, ev, n = edges
        u, v = _canonical(eu, ev)
        n = int(n)
    m = u.shape[0]
    schedule = np.asarray(schedule, np.int64)
    if schedule.shape != (m,) or not np.array_equal(
        np.sort(schedule), np.arange(m)
    ):
        raise ValueError(
            f"schedule must be a permutation of range({m}), got shape "
            f"{schedule.shape}"
        )
    if mutation is not None and mutation not in MUTATIONS:
        raise ValueError(
            f"unknown mutation {mutation!r}; known: {sorted(MUTATIONS)}"
        )

    valid = _valid_mask(u, v, n)
    state = np.zeros(n, np.uint8)  # state-dtype: ok — the model IS the byte
    owner = np.full(n, -1, np.int64)
    matched = np.zeros(m, bool)
    decided = np.zeros(m, bool)
    violations: list = []
    mchd_count = 0

    def report(step, e, invariant, msg):
        err = ApramViolation(
            f"step {step} (edge {e}): {msg}", step=step, event=e,
            invariant=invariant,
        )
        if strict:
            raise err
        violations.append(err)

    for step, e in enumerate(schedule):
        e = int(e)
        if decided[e]:
            report(step, e, "single_pass", "edge touched twice")
            continue
        decided[e] = True
        if not valid[e]:
            continue
        a, b = int(u[e]), int(v[e])
        sa, sb = int(state[a]), int(state[b])

        if mutation is None:
            # Alg. 1, merged reserve+commit: one atomic event.
            if sa == ACC and sb == ACC:
                if owner[a] >= 0 or owner[b] >= 0:
                    report(step, e, "no_double_match",
                           "commit onto an already-owned ACC cell")
                state[a] = state[b] = MCHD
                owner[a] = owner[b] = e
                matched[e] = True
            else:
                matched[e] = False
        elif mutation == "commit_before_reserve":
            if sa == ACC:
                state[a] = MCHD        # the flip: commit u first...
                owner[a] = e
                if sb == ACC and b != a:
                    state[b] = MCHD    # ...then "reserve" (check) v
                    owner[b] = e
                    matched[e] = True
                # on conflict the half-commit is never rolled back
        elif mutation == "skip_partner_check":
            if sa == ACC:
                state[a] = state[b] = MCHD
                owner[a] = owner[b] = e   # may double-book b
                matched[e] = True
        elif mutation == "leak_reservation":
            if sa == ACC and sb == ACC:
                state[a] = state[b] = MCHD
                owner[a] = owner[b] = e
                matched[e] = True
            elif sa == ACC:
                state[a] = RSVD           # reservation never released
        elif mutation == "drop_commit":
            if sa == ACC and sb == ACC:
                matched[e] = True         # ...but the cells never hear
        # ---- event-local invariants (O(1)) --------------------------------
        if matched[e]:
            if int(state[a]) != MCHD or int(state[b]) != MCHD:
                report(step, e, "no_double_match",
                       "matched edge left a non-MCHD endpoint")
            elif owner[a] != e or owner[b] != e:
                report(step, e, "no_double_match",
                       f"matched edge does not own its endpoints "
                       f"(owners {owner[a]}, {owner[b]})")
        else:
            # dead valid edge: some endpoint MCHD, owned by a MATCHED edge
            dead_ok = False
            for w in (a, b):
                o = int(owner[w])
                if int(state[w]) == MCHD and o >= 0 and matched[o]:
                    dead_ok = True
            if not dead_ok:
                report(step, e, "decision_soundness",
                       "edge died without an endpoint matched by a "
                       "committed edge")
        # ---- per-step sweeps (O(n)) ---------------------------------------
        if check_every_step:
            bad = (state != ACC) & (state != MCHD)
            if bad.any():
                w = int(np.flatnonzero(bad)[0])
                report(step, e, "state_domain",
                       f"cell {w} holds out-of-domain value "
                       f"{int(state[w])} between events")
            new_count = int((state == MCHD).sum())
            if new_count < mchd_count:
                report(step, e, "monotone_commit",
                       "an MCHD cell reverted")
            mchd_count = new_count
            zombie = (state == MCHD) & (
                (owner < 0) | ~matched[np.clip(owner, 0, m - 1)]
            )
            if zombie.any():
                w = int(np.flatnonzero(zombie)[0])
                report(step, e, "no_double_match",
                       f"cell {w} is MCHD without a committed owner "
                       f"(owner={int(owner[w])})")

    result = ApramResult(
        u=u, v=v, num_vertices=n, schedule=schedule, matched=matched,
        decided=decided, state=state, owner=owner, violations=violations,
    )
    if check_quiescence:
        try:
            result.check_quiescent()
        except ApramViolation as err:
            if strict:
                raise
            violations.append(err)
    return result
