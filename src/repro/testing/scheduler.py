"""Adversarial schedulers for the APRAM model.

A *schedule* is a permutation of ``range(m)`` — the order in which the m
atomic edge events hit the vertex cells. The APRAM adversary controls
nothing else. This module is the zoo of adversaries the conformance suite
and the fuzzer draw from:

* :func:`stream_order` — the identity schedule; the fixpoint every JAX
  matcher in this repo actually executes (sequential index-order greedy).
* :func:`random_schedule` — seeded uniform permutation.
* :func:`round_robin` — ``t`` "threads" are dealt contiguous blocks of
  the stream and the scheduler interleaves them one event per thread per
  round. This is the classic APRAM adversary: commit visibility from one
  thread's early edges lands between another thread's edges.
* :func:`hub_contention` — worst-case contention: events sorted so that
  edges touching the highest-degree vertices fire first (ties broken by
  reversed stream order). Maximizes the number of conflicting commits on
  shared cells early in the run.
* :func:`exhaustive_schedules` — every one of the m! interleavings, for
  tiny instances only (guarded by :data:`MAX_EXHAUSTIVE_EVENTS`).
* :func:`sweep` — convenience: run a named battery of the above through
  :func:`repro.testing.apram.run_schedule` and return the results.
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.testing.apram import ApramResult, run_schedule

#: Exhaustive enumeration is m! schedules; 8 events = 40320 runs of the
#: numpy model, a couple of seconds. Anything past this is a harness bug.
MAX_EXHAUSTIVE_EVENTS = 8


def _num_events(edges) -> int:
    if hasattr(edges, "num_vertices"):
        return int(np.asarray(edges.u).shape[0])
    return int(np.asarray(edges[0]).shape[0])


def stream_order(m: int) -> np.ndarray:
    """The identity schedule — the one every production matcher realizes."""
    return np.arange(m, dtype=np.int64)


def random_schedule(m: int, seed: int) -> np.ndarray:
    """Seeded uniform-random permutation of the events."""
    return np.random.default_rng(seed).permutation(m).astype(np.int64)


def round_robin(m: int, threads: int = 4) -> np.ndarray:
    """Deal the stream into ``threads`` contiguous blocks, then interleave
    one event per thread per round (thread 0 gets the remainder-padded
    first block). Models synchronous threads each scanning a shard of the
    stream at the same rate."""
    threads = max(1, min(int(threads), m)) if m else 1
    blocks = np.array_split(np.arange(m, dtype=np.int64), threads)
    out: List[int] = []
    for round_idx in range(max((len(b) for b in blocks), default=0)):
        for b in blocks:
            if round_idx < len(b):
                out.append(int(b[round_idx]))
    return np.asarray(out, np.int64)


def hub_contention(edges) -> np.ndarray:
    """Contention-first schedule: order events by descending max endpoint
    degree, breaking ties by *reversed* stream order, so the hub's edges
    (and among them the latest ones) fire before anything else. On a star
    this serializes every conflicting commit onto the hub cell up front —
    the opposite extreme from the stream order the matchers execute."""
    if hasattr(edges, "num_vertices"):
        u = np.asarray(edges.u, np.int64)
        v = np.asarray(edges.v, np.int64)
        n = int(edges.num_vertices)
    else:
        u, v, n = (np.asarray(edges[0], np.int64),
                   np.asarray(edges[1], np.int64), int(edges[2]))
    m = u.shape[0]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    valid = (lo != hi) & (lo >= 0) & (hi < n)
    deg = np.zeros(n + 1, np.int64)
    np.add.at(deg, np.where(valid, lo, n), 1)
    np.add.at(deg, np.where(valid, hi, n), 1)
    deg[n] = 0  # invalid-edge bucket
    edge_deg = np.maximum(deg[np.where(valid, lo, n)],
                          deg[np.where(valid, hi, n)])
    # lexsort: primary = -degree, secondary = -stream index
    order = np.lexsort((-np.arange(m), -edge_deg))
    return order.astype(np.int64)


def exhaustive_schedules(m: int) -> Iterator[np.ndarray]:
    """Yield every permutation of ``range(m)``. Refuses m >
    :data:`MAX_EXHAUSTIVE_EVENTS` — that is 40320 schedules already."""
    if m > MAX_EXHAUSTIVE_EVENTS:
        raise ValueError(
            f"exhaustive enumeration of {m}! schedules refused "
            f"(m > {MAX_EXHAUSTIVE_EVENTS}); use random_schedule sweeps"
        )
    for perm in itertools.permutations(range(m)):
        yield np.asarray(perm, np.int64)


def sweep(
    edges,
    *,
    seeds: Sequence[int] = (0, 1, 2, 3),
    threads: Sequence[int] = (2, 4),
    mutation: Optional[str] = None,
    strict: bool = True,
) -> List[ApramResult]:
    """Run the standard adversary battery over one instance.

    Battery = stream order, hub contention, round-robin at each thread
    count, and one random schedule per seed. Returns the
    :class:`~repro.testing.apram.ApramResult` list (strict mode raises at
    the first invariant violation instead)."""
    m = _num_events(edges)
    schedules = [stream_order(m), hub_contention(edges)]
    schedules += [round_robin(m, t) for t in threads]
    schedules += [random_schedule(m, s) for s in seeds]
    return [
        run_schedule(edges, s, mutation=mutation, strict=strict)
        for s in schedules
    ]
