"""APRAM interleaving conformance subsystem (DESIGN.md §13).

Skipper's headline claim is schedule-independence: the merged
reserve+commit step is safe in the asynchronous PRAM model — ANY
interleaving of per-edge events over the single-byte vertex cells ends in
a valid maximal matching after one pass. The production matchers in this
repo only ever execute the one deterministic schedule JAX traces, so the
property the paper is *about* needs its own ground-truth model:

* :mod:`repro.testing.apram` — a step-level numpy model of the protocol
  where each edge's reserve+commit is one atomic event, with per-step
  invariant checks (state domain, no double-match, monotone commit) and
  quiescence checks (validity + maximality via ``core/validate``), plus
  seeded protocol *mutations* (commit-before-reserve and friends) that
  the harness must catch.
* :mod:`repro.testing.scheduler` — the adversarial scheduler zoo:
  seeded-random, round-robin thread interleavings, hub-contention
  worst case, and exhaustive enumeration of every interleaving for tiny
  instances.
* :mod:`repro.testing.oracle` — differential conformance: pin any
  production matching as ONE reachable APRAM trace of the same edge
  stream (the matched-first witness schedule), executed through the
  checked model rather than trusted as a theorem.

This package is test infrastructure: it depends on numpy and (for the
quiescence validity check and entry-point pins) the production ``repro``
modules, never the other way around.
"""
from repro.testing.apram import (
    ApramResult,
    ApramViolation,
    MUTATIONS,
    run_schedule,
)
from repro.testing.oracle import (
    ConformanceError,
    bipartite_stream,
    pin_entry_points,
    pin_trace,
    witness_schedule,
)
from repro.testing.scheduler import (
    MAX_EXHAUSTIVE_EVENTS,
    exhaustive_schedules,
    hub_contention,
    random_schedule,
    round_robin,
    stream_order,
    sweep,
)

__all__ = [
    "ApramResult",
    "ApramViolation",
    "MUTATIONS",
    "run_schedule",
    "ConformanceError",
    "bipartite_stream",
    "pin_entry_points",
    "pin_trace",
    "witness_schedule",
    "MAX_EXHAUSTIVE_EVENTS",
    "exhaustive_schedules",
    "hub_contention",
    "random_schedule",
    "round_robin",
    "stream_order",
    "sweep",
]
