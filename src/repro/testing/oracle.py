"""Differential conformance: pin production matchings as APRAM traces.

The bridge theorem (DESIGN.md §13): a mask over a canonical edge stream is
a *reachable trace* of the APRAM reservation protocol **iff** it is a
valid maximal matching of that stream — and the witness is executable.
:func:`witness_schedule` orders the matched events first (in stream
order), then everything else; running that schedule through the *checked*
step-level model must reproduce the mask decision-for-decision:

* if the mask double-books a vertex, the second adjacent "matched" event
  finds a non-ACC cell and dies → mismatch (and the model's own
  ``no_double_match`` check fires);
* if the mask is non-maximal, some free edge with both endpoints
  uncovered comes up in the tail and the model commits it → mismatch.

So :func:`pin_trace` doesn't *trust* the theorem — it executes the
witness under full per-step invariant checking and compares. Every
production entry point (``skipper``, ``skipper_match``,
``distributed_skipper``, ``bmatch_assign`` via :func:`bipartite_stream`,
the chaos-recover ladder) is pinned this way by the conformance suite;
:func:`pin_entry_points` bundles the single-process matrix.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.testing.apram import ApramResult, run_schedule


class ConformanceError(AssertionError):
    """A production mask is not a reachable APRAM trace.

    ``first_mismatch`` is the first stream index where the model's
    decision differs from the production mask (-1 when the failure came
    from the model's own invariant machinery instead)."""

    def __init__(self, message: str, *, first_mismatch: int = -1):
        super().__init__(message)
        self.first_mismatch = first_mismatch


def witness_schedule(edges, mask) -> np.ndarray:
    """The executable witness: matched events first (stream order), then
    the rest (stream order). For the true protocol this is the schedule
    under which a valid maximal mask reproduces itself exactly."""
    mask = np.asarray(mask, bool)
    idx = np.arange(mask.shape[0], dtype=np.int64)
    return np.concatenate([idx[mask], idx[~mask]])


def pin_trace(edges, mask, *, label: str = "") -> ApramResult:
    """Assert ``mask`` is a reachable APRAM trace of ``edges``.

    Runs the matched-first witness schedule through the fully-checked
    model (``strict=True`` — any protocol invariant failing raises
    :class:`~repro.testing.apram.ApramViolation` from underneath) and then
    requires the model's decisions to equal ``mask`` bit for bit.

    Args:
        edges: ``EdgeList`` or ``(u, v, num_vertices)`` tuple — the SAME
            stream (order included) the production matcher consumed.
        mask: bool[m] production match mask.
        label: prefixed to failure messages (name the entry point).

    Returns:
        The witness :class:`~repro.testing.apram.ApramResult`.
    """
    mask = np.asarray(mask, bool)
    result = run_schedule(edges, witness_schedule(edges, mask), strict=True)
    if not np.array_equal(result.matched, mask):
        k = int(np.flatnonzero(result.matched != mask)[0])
        who = f"{label}: " if label else ""
        raise ConformanceError(
            f"{who}mask is not a reachable APRAM trace: first divergence "
            f"at stream index {k} — edge ({result.u[k]}, {result.v[k]}) is "
            f"{'matched' if mask[k] else 'unmatched'} in the production "
            f"mask but the witness schedule "
            f"{'matched' if result.matched[k] else 'killed'} it "
            f"({'mask double-books a vertex' if mask[k] else 'mask is not maximal'})",
            first_mismatch=k,
        )
    return result


def bipartite_stream(
    token_ids, expert_ids, *, num_tokens: int, num_experts: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Map a b-matching candidate stream to a plain graph stream.

    At ``token_budget=1`` / ``expert_capacity=1`` the capacitated router
    IS unit matching on the bipartite graph with tokens at ids
    ``[0, num_tokens)`` and experts at ``num_tokens + expert_id`` — so
    ``bmatch_assign``'s accept mask can be pinned with :func:`pin_trace`
    on the stream this returns. Invalid candidates (``token_id < 0``)
    map to ``u = v = -1`` (invalid under the model's predicate)."""
    tok = np.asarray(token_ids, np.int64)
    exp = np.asarray(expert_ids, np.int64)
    bad = tok < 0
    u = np.where(bad, -1, tok)
    v = np.where(bad, -1, num_tokens + exp)
    return u, v, int(num_tokens) + int(num_experts)


def pin_entry_points(
    edges,
    *,
    specs: Optional[Sequence] = None,
    window: int = 64,
    tile_size: int = 32,
    include_pallas: bool = True,
    include_distributed: bool = True,
    include_chaos: bool = True,
) -> Dict[str, ApramResult]:
    """Pin the single-process production matrix on one edge list.

    Runs each entry point at every state width in ``specs`` (default:
    ``StateSpec.u8()`` and ``StateSpec.legacy_i32()``) and
    :func:`pin_trace`-s its mask. Forced multi-device
    ``distributed_skipper`` lives in the subprocess tests, not here.

    Returns ``{"<entry>@<spec>": ApramResult}``; raises
    :class:`ConformanceError` / ``ApramViolation`` on the first failure.
    """
    from repro.core.distributed import distributed_skipper
    from repro.core.faults import FaultPlan
    from repro.core.skipper import skipper
    from repro.core.statespec import StateSpec
    from repro.kernels.skipper_match.ops import skipper_match

    if specs is None:
        specs = (StateSpec.u8(), StateSpec.legacy_i32())

    def _tag(spec):
        if spec == StateSpec.u8():
            return "u8"
        if spec == StateSpec.legacy_i32():
            return "legacy_i32"
        return f"{spec.vmem}-{spec.combine}"

    out: Dict[str, ApramResult] = {}
    for spec in specs:
        tag = _tag(spec)

        res, _ = skipper(edges, tile_size=tile_size, spec=spec)
        out[f"skipper@{tag}"] = pin_trace(
            edges, np.asarray(res.match_mask), label=f"skipper@{tag}"
        )

        res = skipper_match(
            edges, window=window, tile_size=tile_size, backend="xla",
            spec=spec,
        )
        out[f"skipper_match_xla@{tag}"] = pin_trace(
            edges, np.asarray(res.match_mask),
            label=f"skipper_match_xla@{tag}",
        )

        if include_pallas:
            res = skipper_match(
                edges, window=window, tile_size=tile_size,
                backend="pallas", interpret=True, spec=spec,
            )
            out[f"skipper_match_pallas@{tag}"] = pin_trace(
                edges, np.asarray(res.match_mask),
                label=f"skipper_match_pallas@{tag}",
            )

        if include_distributed:
            res, _stats = distributed_skipper(
                edges, block_size=tile_size, tile_size=tile_size, spec=spec
            )
            out[f"distributed@{tag}"] = pin_trace(
                edges, np.asarray(res.match_mask),
                label=f"distributed@{tag}",
            )

        if include_chaos:
            plan = FaultPlan(seed=7, drop_proposals=0.25, corrupt_state=0.05)
            res, _report = skipper_match(
                edges, window=window, tile_size=tile_size, backend="xla",
                faults=plan, on_fault="recover", spec=spec,
            )
            out[f"chaos_recover@{tag}"] = pin_trace(
                edges, np.asarray(res.match_mask),
                label=f"chaos_recover@{tag}",
            )
    return out
