"""Batched serving driver: prefill + decode with continuous slot refill.

A minimal production serving loop: a request queue feeds fixed decode slots;
finished sequences (EOS or budget) free their slot, which is refilled by
prefilling the next request — the static-shape analogue of continuous
batching (slot refill re-runs prefill for the joining request only).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 12 --slots 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config, get_smoke_config
from repro.launch import adapters
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step

EOS = 2


def serve(arch: str, smoke: bool, num_requests: int, slots: int,
          prompt_len: int, max_new: int, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    assert cfg.family in ("dense", "moe", "ssm", "hybrid"), (
        "serving demo drives the decoder-only families"
    )
    rng = np.random.default_rng(seed)
    requests: List[np.ndarray] = [
        rng.integers(3, cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(num_requests)
    ]
    max_len = prompt_len + max_new

    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        params = adapters.init_fn(jax.random.PRNGKey(seed), cfg)
        serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

        def prefill_one(prompt: np.ndarray):
            batch = {"tokens": jnp.asarray(prompt)[None]}
            logits, cache = adapters.prefill_fn(params, batch, cfg, max_len=max_len)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            return nxt, cache

        # slot state: per-slot caches batched by stacking later; for clarity
        # (and CPU scale) each slot holds its own cache pytree.
        queue = list(range(num_requests))
        active = {}
        outputs = {i: [] for i in range(num_requests)}
        t0 = time.time()
        decoded = 0

        def refill(slot):
            if not queue:
                return None
            rid = queue.pop(0)
            nxt, cache = prefill_one(requests[rid])
            return {"rid": rid, "tokens": nxt, "cache": cache, "n": 0}

        slot_state = {s: refill(s) for s in range(slots)}
        while any(v is not None for v in slot_state.values()):
            for s, st in list(slot_state.items()):
                if st is None:
                    continue
                tok, cache = serve_step(params, st["cache"], st["tokens"])
                outputs[st["rid"]].append(int(tok[0, 0]))
                decoded += 1
                st["tokens"], st["cache"], st["n"] = tok, cache, st["n"] + 1
                if int(tok[0, 0]) == EOS or st["n"] >= max_new:
                    slot_state[s] = refill(s)
        dt = time.time() - t0
        print(f"[serve] {num_requests} requests, {decoded} tokens decoded in "
              f"{dt:.1f}s ({decoded/dt:.1f} tok/s, {slots} slots)")
    return outputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, args.smoke, args.requests, args.slots,
          args.prompt_len, args.max_new)


if __name__ == "__main__":
    main()
