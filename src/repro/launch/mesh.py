"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (gradient all-reduce over DCI),
"model" stays intra-pod where ICI bandwidth lives.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count before first jax use).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return compat.make_mesh((n // model_axis, model_axis), ("data", "model"))
