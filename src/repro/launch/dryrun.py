import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below may now import jax.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero real allocation (ShapeDtypeStruct
inputs, abstract params):

  * proof the sharding config is coherent (compile succeeds),
  * memory_analysis()  -> bytes/device (checked against v5e HBM),
  * cost_analysis()    -> FLOPs / bytes for the roofline terms,
  * the partitioned HLO's collective mix -> collective bytes.

Results are persisted incrementally to experiments/dryrun/*.json so reruns
only compile missing cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCH_IDS, TrainConfig, get_config, get_shape, runnable_cells
from repro.launch import adapters
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim import adamw
from repro.parallel.sharding import param_shardings
from repro.roofline import analysis as roofline

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _opt_moment_dtype(cfg):
    return jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32


def count_params(tree) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_params(cfg, total: int) -> int:
    """MoE: only top-k of E experts touch each token."""
    if cfg.num_experts > 0:
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_experts * cfg.num_layers
        dense = total - expert
        return dense + expert * cfg.num_experts_per_tok // cfg.num_experts
    return total


def _axis_size(mesh, axes):
    sizes = dict(mesh.shape)
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes[a]
    return total


def pick_microbatches(cfg, shape, n_fsdp: int) -> int:
    """Gradient-accumulation factor so per-microbatch activations fit HBM:
    target ~64Mi bf16 activation elements per device per microbatch
    (tokens x d_model), clamped so every microbatch still spans the fsdp
    axis. The standard batch/memory lever at scale."""
    if shape.kind != "train":
        return 1
    budget_elems = 64 * 2**20
    if cfg.family == "audio":
        # enc-dec: decoder cross-attention score buffers add a ~4x factor
        budget_elems //= 4
    tokens_per_dev = shape.global_batch * shape.seq_len / n_fsdp
    mb = 1
    while (
        tokens_per_dev / mb * cfg.d_model > budget_elems
        and shape.global_batch // (mb * 2) >= n_fsdp
        and (shape.global_batch % (mb * 2)) == 0
    ):
        mb *= 2
    return mb


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.reshape(-1))
    n_fsdp = n_chips // 16  # model axis is always 16
    tcfg = TrainConfig(microbatches=pick_microbatches(cfg, shape, n_fsdp))

    abstract_params = jax.eval_shape(
        lambda: adapters.init_fn(jax.random.PRNGKey(0), cfg)
    )
    p_shardings = param_shardings(abstract_params, mesh)
    n_total = count_params(abstract_params)
    n_active = active_params(cfg, n_total)

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            abstract_opt = jax.eval_shape(
                lambda: adamw.init_state(abstract_params, tcfg, _opt_moment_dtype(cfg))
            )
            o_shardings = adamw.AdamWState(
                step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                mu=param_shardings(abstract_opt.mu, mesh),
                nu=param_shardings(abstract_opt.nu, mesh),
            )
            batch, b_shardings = adapters.batch_specs(cfg, shape, mesh)
            step_fn = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shardings, o_shardings, b_shardings),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(abstract_params, abstract_opt, batch)
        elif shape.kind == "prefill":
            batch, b_shardings = adapters.batch_specs(cfg, shape, mesh)
            step_fn = make_prefill_step(cfg)
            # pin OUTPUT shardings: prefill CREATES the KV cache; without
            # out_shardings XLA may replicate it (observed: whisper prefill
            # ballooning to 161 GiB/device).
            from jax.sharding import NamedSharding, PartitionSpec as P
            _, c_shardings = adapters.cache_specs(cfg, shape, mesh)
            fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            bspec = fsdp if shape.global_batch % _axis_size(mesh, fsdp) == 0 else None
            vspec = "model" if cfg.vocab_size % _axis_size(mesh, "model") == 0 else None
            logit_sharding = NamedSharding(mesh, P(bspec, None, vspec))
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shardings, b_shardings),
                out_shardings=(logit_sharding, c_shardings),
            )
            lowered = jitted.lower(abstract_params, batch)
        else:  # decode
            cache, c_shardings = adapters.cache_specs(cfg, shape, mesh)
            tokens, t_sharding = adapters.decode_token_specs(cfg, shape, mesh)
            step_fn = make_serve_step(cfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shardings, c_shardings, t_sharding),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(abstract_params, cache, tokens)

        compiled = lowered.compile()

    terms = roofline.analyze(compiled)
    mf = roofline.model_flops(cfg, shape, n_active, n_total)
    mf_per_device = mf / n_chips
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "microbatches": tcfg.microbatches,
        "params_total": n_total,
        "params_active": n_active,
        "model_flops_total": mf,
        "model_flops_per_device": mf_per_device,
        "useful_flops_ratio": (mf_per_device / terms.flops) if terms.flops else None,
        "fits_hbm": (terms.bytes_per_device - terms.cpu_convert_artifact)
        <= roofline.HBM_PER_CHIP,
        "hbm_gib": terms.bytes_per_device / 2**30,
        "hbm_gib_tpu_corrected": (terms.bytes_per_device - terms.cpu_convert_artifact) / 2**30,
        **terms.to_dict(),
    }
    return record


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    safe = arch.replace(".", "_")
    return os.path.join(OUT_DIR, f"{safe}__{shape_name}__{mesh}{tag}.json")


def run_cell(arch, shape_name, multi_pod, force=False, tag="") -> Optional[Dict]:
    path = cell_path(arch, shape_name, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    try:
        record = lower_cell(arch, shape_name, multi_pod)
        record["compile_s"] = time.time() - t0
        record["ok"] = True
    except Exception as e:  # record failures — they are bugs to fix
        record = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "ok": False, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
            "compile_s": time.time() - t0,
        }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = runnable_cells()
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    n_ok = n_fail = 0
    for arch, shapes in cells.items():
        if args.arch and arch != args.arch:
            continue
        for shape_name in shapes:
            if args.shape and shape_name != args.shape:
                continue
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, force=args.force)
                status = "OK " if rec.get("ok") else "FAIL"
                if rec.get("ok"):
                    n_ok += 1
                    print(
                        f"{status} {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
                        f"hbm={rec['hbm_gib']:.2f}GiB fits={rec['fits_hbm']} "
                        f"dom={rec['dominant']:10s} "
                        f"t_c={rec['compute_s']*1e3:.2f}ms t_m={rec['memory_s']*1e3:.2f}ms "
                        f"t_x={rec['collective_s']*1e3:.2f}ms "
                        f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
                        f"[{rec['compile_s']:.0f}s]",
                        flush=True,
                    )
                else:
                    n_fail += 1
                    print(f"{status} {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
                          f"{rec['error'][:160]}", flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
