"""End-to-end training driver with fault tolerance.

Production behaviors demonstrated at laptop scale (and identical in shape to
the multi-pod deployment — the mesh/config swap is the only difference):

  * deterministic sharded data: batch(step, host) is a pure function, so a
    restart replays nothing and an elastic re-shard changes only host_id
    mapping;
  * checkpoint/restart: versioned, digest-checked, async; auto-resume from
    the latest step (kill -9 at any point and re-run the same command);
  * straggler/failure handling at the job level: the launcher re-executes
    the same command; in-step determinism makes the retry idempotent.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro import compat
from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.data import DataConfig, batch_for_step
from repro.launch import adapters
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.optim import adamw
from repro.parallel.sharding import param_shardings


def build_batch(cfg, dcfg, step: int):
    tokens, mask = batch_for_step(step, dcfg)
    batch = {"tokens": jnp.asarray(tokens), "mask": jnp.asarray(mask)}
    if cfg.family == "vlm":
        b, s = tokens.shape
        n_img = max(4, s // 8)
        gh = int(np.sqrt(n_img))
        n_img = gh * gh
        from repro.models.vlm import make_mrope_positions

        rng = np.random.default_rng(step)
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, n_img, cfg.d_model)).astype(np.float32)
        )
        batch["mrope_positions"] = make_mrope_positions(b, s + n_img, n_img, (gh, gh))
    if cfg.family == "audio":
        rng = np.random.default_rng(step)
        batch["frames"] = jnp.asarray(
            rng.normal(size=(tokens.shape[0], cfg.encoder_frames, cfg.d_model))
            .astype(np.float32)
        )
    return batch


def train(arch: str, smoke: bool, steps: int, batch_size: int, seq_len: int,
          ckpt_dir: str | None, checkpoint_every: int = 50,
          microbatches: int = 1, log_every: int = 10):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    tcfg = TrainConfig(total_steps=steps, warmup_steps=max(1, steps // 10),
                       microbatches=microbatches,
                       checkpoint_every=checkpoint_every)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      batch_per_host=batch_size)

    mesh = make_host_mesh()
    params = adapters.init_fn(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = adamw.init_state(params, tcfg)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        params, opt_state, meta = ckpt.restore(None, params, opt_state)
        start_step = meta["step"]
        print(f"[train] resumed from step {start_step}")

    with compat.set_mesh(mesh):
        p_shardings = param_shardings(params, mesh)
        params = jax.device_put(params, p_shardings)
        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

        losses = []
        t0 = time.time()
        for step in range(start_step, steps):
            batch = build_batch(cfg, dcfg, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % log_every == 0:
                dt = time.time() - t0
                tps = log_every * batch_size * seq_len / dt
                print(
                    f"[train] step {step+1:5d} loss {losses[-1]:.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                    f"{tps:,.0f} tok/s",
                    flush=True,
                )
                t0 = time.time()
            if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(step + 1,
                          jax.device_get(params),  # host-sync: ok (checkpoint)
                          jax.device_get(opt_state))  # host-sync: ok (checkpoint)
        if ckpt:
            ckpt.save(steps,
                      jax.device_get(params),  # host-sync: ok (final checkpoint)
                      jax.device_get(opt_state),  # host-sync: ok (final checkpoint)
                      block=True)
            ckpt.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, microbatches=args.microbatches)
    print(f"[train] final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
