"""Per-family adapters: one uniform interface over the model zoo.

  init_fn(key, cfg)                      -> params
  train_logits(params, batch, cfg)       -> (logits, targets, loss_mask)
  prefill_fn(params, batch, cfg)         -> (logits, cache)
  decode_fn(params, cache, tokens, cfg)  -> (logits, cache)
  input_specs(cfg, shape, mesh)          -> (batch pytree of ShapeDtypeStruct,
                                             matching sharding pytree)

``input_specs`` is the dry-run contract: weak-type-correct ShapeDtypeStruct
stand-ins for every model input, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T, ssm as S, hybrid as H, encdec as E, vlm as V
from repro.parallel.sharding import rules_for_mesh

VLM_IMAGE_TOKENS = 1024          # stub vision prefix (32x32 grid)
VLM_GRID = (32, 32)


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_fn(key, cfg: ModelConfig):
    return {
        "dense": T.init_params, "moe": T.init_params, "vlm": V.init_params,
        "audio": E.init_params, "ssm": S.init_params, "hybrid": H.init_params,
    }[cfg.family](key, cfg)


def _shifted(tokens: jax.Array, mask: jax.Array):
    """Next-token targets aligned with the *unsliced* logits: target[t] =
    token[t+1]; the final position is masked out. Avoids materializing a
    second [B, S, V] slice of the logits."""
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1
    )
    tmask = jnp.concatenate(
        [mask[:, 1:], jnp.zeros_like(mask[:, :1])], axis=1
    )
    return targets, tmask


def train_hidden(params, batch: Dict[str, Any], cfg: ModelConfig):
    """-> (hidden [B,S,D], head weight, transpose_head, targets, loss_mask).

    The loss path never materializes the full [B, S, V] logits: the head
    projection + CE run chunked over the sequence (steps.chunked_ce)."""
    if cfg.family in ("dense", "moe"):
        hidden, head = T.forward(params, batch["tokens"], cfg, return_hidden=True)
        targets, tmask = _shifted(batch["tokens"], batch["mask"])
        return hidden, head, False, targets, tmask
    if cfg.family == "vlm":
        hidden, head = V.forward(
            params, batch["tokens"], batch["image_embeds"],
            batch["mrope_positions"], cfg, return_hidden=True,
        )
        n_img = batch["image_embeds"].shape[1]
        targets, tmask = _shifted(batch["tokens"], batch["mask"])
        pad_t = jnp.zeros((targets.shape[0], n_img), targets.dtype)
        pad_m = jnp.zeros((targets.shape[0], n_img), tmask.dtype)
        return (hidden, head, False,
                jnp.concatenate([pad_t, targets], 1),
                jnp.concatenate([pad_m, tmask], 1))
    if cfg.family == "audio":
        hidden, head = E.forward(
            params, batch["tokens"], batch["frames"], cfg, return_hidden=True
        )
        targets, tmask = _shifted(batch["tokens"], batch["mask"])
        return hidden, head, True, targets, tmask
    if cfg.family == "ssm":
        hidden, head = S.forward(params, batch["tokens"], cfg, return_hidden=True)
        targets, tmask = _shifted(batch["tokens"], batch["mask"])
        return hidden, head, False, targets, tmask
    if cfg.family == "hybrid":
        hidden, head = H.forward(params, batch["tokens"], cfg, return_hidden=True)
        targets, tmask = _shifted(batch["tokens"], batch["mask"])
        return hidden, head, False, targets, tmask
    raise ValueError(cfg.family)


def prefill_fn(params, batch, cfg: ModelConfig, max_len: Optional[int] = None):
    if cfg.family in ("dense", "moe"):
        return T.prefill(params, batch["tokens"], cfg, max_len=max_len)
    if cfg.family == "vlm":
        return V.prefill(
            params, batch["tokens"], batch["image_embeds"],
            batch["mrope_positions"], cfg, max_len=max_len,
        )
    if cfg.family == "audio":
        return E.prefill(params, batch["tokens"], batch["frames"], cfg, max_len=max_len)
    if cfg.family == "ssm":
        return S.prefill(params, batch["tokens"], cfg)
    if cfg.family == "hybrid":
        return H.prefill(params, batch["tokens"], cfg, max_len=max_len)
    raise ValueError(cfg.family)


def decode_fn(params, cache, tokens, cfg: ModelConfig):
    mod = {"dense": T, "moe": T, "vlm": T, "audio": E, "ssm": S, "hybrid": H}[cfg.family]
    return mod.decode_step(params, cache, tokens, cfg)


def init_cache_fn(cfg: ModelConfig, batch: int, max_len: int):
    mod = {"dense": T, "moe": T, "vlm": T, "audio": E, "ssm": S, "hybrid": H}[cfg.family]
    return mod.init_cache(cfg, batch, max_len)


# ------------------------------------------------------------ input specs --
def _fsdp(mesh_names):
    axes = tuple(a for a in ("pod", "data") if a in mesh_names)
    return axes if axes else (mesh_names[0],)


def _maybe(dim: int, axes, mesh: Mesh) -> Optional[Any]:
    if axes is None:
        return None
    sizes = dict(mesh.shape)
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes[a]
    return axes if dim % total == 0 else None


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(ShapeDtypeStructs, NamedShardings) for the *train/prefill* batch."""
    names = mesh.axis_names
    fsdp = _fsdp(names)
    b, s = shape.global_batch, shape.seq_len
    dt = _dt(cfg)
    bspec = _maybe(b, fsdp, mesh)

    def sds(shp, dtype, spec):
        return (
            jax.ShapeDtypeStruct(shp, dtype),
            NamedSharding(mesh, P(*spec)),
        )

    batch, shards = {}, {}
    if cfg.family == "vlm":
        n_img = min(VLM_IMAGE_TOKENS, s // 2)
        batch["tokens"], shards["tokens"] = sds((b, s - n_img), jnp.int32, (bspec, None))
        batch["image_embeds"], shards["image_embeds"] = sds(
            (b, n_img, cfg.d_model), dt, (bspec, None, None)
        )
        batch["mrope_positions"], shards["mrope_positions"] = sds(
            (3, b, s), jnp.int32, (None, bspec, None)
        )
        batch["mask"], shards["mask"] = sds((b, s - n_img), jnp.bool_, (bspec, None))
    elif cfg.family == "audio":
        batch["tokens"], shards["tokens"] = sds((b, s), jnp.int32, (bspec, None))
        batch["frames"], shards["frames"] = sds(
            (b, cfg.encoder_frames, cfg.d_model), dt, (bspec, None, None)
        )
        batch["mask"], shards["mask"] = sds((b, s), jnp.bool_, (bspec, None))
    else:
        batch["tokens"], shards["tokens"] = sds((b, s), jnp.int32, (bspec, None))
        batch["mask"], shards["mask"] = sds((b, s), jnp.bool_, (bspec, None))
    return batch, shards


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(cache ShapeDtypeStructs, shardings) for decode cells — a KV/state
    cache already filled to shape.seq_len."""
    names = mesh.axis_names
    fsdp = _fsdp(names)
    tp = "model" if "model" in names else None
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache_fn(cfg, b, s))

    def spec_for(path_key: str, leaf) -> P:
        shp = leaf.shape
        if path_key in ("pos",):
            return P()
        if leaf.ndim == 0:
            return P()
        if path_key in ("k", "v", "cross_k", "cross_v"):
            # [L(or apps), B, W, H, hd]
            _, bb, w, h, _ = shp
            bspec = _maybe(bb, fsdp, mesh)
            hspec = _maybe(h, tp, mesh) if tp else None
            wspec = None
            if hspec is None and tp:
                wspec = _maybe(w, tp, mesh)
            if bspec is None:       # B=1 long-context: spread seq over fsdp
                wspec2 = _maybe(w, fsdp, mesh)
                if wspec2 is not None and wspec is None:
                    wspec = wspec2
                elif wspec2 is not None and wspec is not None:
                    pass
            return P(None, bspec, wspec, hspec, None)
        if path_key == "conv":
            # [..., B, W-1, C]
            bspec = _maybe(shp[-3], fsdp, mesh)
            cspec = _maybe(shp[-1], tp, mesh) if tp else None
            lead = (None,) * (leaf.ndim - 3)
            return P(*lead, bspec, None, cspec)
        if path_key == "ssm":
            # [..., B, H, P, N]
            bspec = _maybe(shp[-4], fsdp, mesh)
            hspec = _maybe(shp[-3], tp, mesh) if tp else None
            lead = (None,) * (leaf.ndim - 4)
            return P(*lead, bspec, hspec, None, None)
        return P()

    shards = {
        k: NamedSharding(mesh, spec_for(k, v)) for k, v in cache.items()
    }
    return cache, shards


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    fsdp = _fsdp(mesh.axis_names)
    b = shape.global_batch
    bspec = _maybe(b, fsdp, mesh)
    return (
        jax.ShapeDtypeStruct((b, 1), jnp.int32),
        NamedSharding(mesh, P(bspec, None)),
    )
