"""Step builders: train_step (fwd+bwd+AdamW, microbatch accumulation),
prefill_step, serve_step. These are the exact functions the dry-run lowers
and the drivers execute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.launch import adapters
from repro.optim import adamw


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Shard-friendly CE: the gold logit is extracted with a fused
    one-hot-compare-reduce instead of take_along_axis, which under a
    vocab-sharded [B, S, V] tensor lowers to a per-shard masked sum + tiny
    psum rather than an all-gather of the logits (the largest activation in
    every LM). Upcast to f32 happens per-element inside the reductions."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == targets[..., None], lf, 0.0), axis=-1
    )
    ce = logz - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(logz)
    m = mask.astype(jnp.float32)
    return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)


CE_CHUNK = 1024


def chunked_ce(hidden, head_w, transpose_head, targets, mask,
               z_loss: float = 0.0, chunk: int = CE_CHUNK) -> jax.Array:
    """Head projection + CE scanned over sequence chunks: the full [B, S, V]
    logits tensor (the largest activation of LM training by far) never
    exists — per chunk only [B, chunk, V/shards] lives in HBM."""
    from repro.models import layers as L

    b, s, d = hidden.shape
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(b, nc, c, *x.shape[2:]), 1, 0)

    def step(carry, xs):
        ce_sum, m_sum = carry
        h_c, t_c, m_c = xs
        logits = L.lm_head(h_c, head_w, transpose=transpose_head)
        lf = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lf, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == t_c[..., None], lf, 0.0), axis=-1)
        ce = logz - gold
        if z_loss:
            ce = ce + z_loss * jnp.square(logz)
        mf = m_c.astype(jnp.float32)
        return (ce_sum + jnp.sum(ce * mf), m_sum + jnp.sum(mf)), None

    (ce_sum, m_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (to_chunks(hidden), to_chunks(targets), to_chunks(mask)),
    )
    return ce_sum / jnp.maximum(m_sum, 1.0)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        hidden, head, transpose_head, targets, mask = adapters.train_hidden(
            params, batch, cfg
        )
        return chunked_ce(hidden, head, transpose_head, targets, mask, tcfg.z_loss)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1: gradient accumulation via lax.scan over batch slices —
    the standard memory/overlap lever (each microbatch's backward reduce
    overlaps the next microbatch's compute on real hardware).
    """
    loss_fn = make_loss_fn(cfg, tcfg)

    # Accumulation dtype: f32 by default; bf16 for the >=100B configs that
    # already run bf16 Adam moments — at 405B, an f32 gradient accumulator is
    # 1.62 TB and alone overflows a 256-chip v5e pod (EXPERIMENTS §Perf #11).
    acc_dtype = (
        jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32
    )

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def slice_mb(x, i, axis=0):
                bsz = x.shape[axis] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * bsz, bsz, axis=axis)

            def acc_step(carry, i):
                gsum, lsum = carry
                # batch axis is 1 for [3, B, S] mrope position streams
                mbatch = {
                    k: slice_mb(v, i, axis=1 if k == "mrope_positions" else 0)
                    for k, v in batch.items()
                }
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), gsum, grads
                )
                return (gsum, lsum + loss), None

            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                acc_step, (gsum0, 0.0), jnp.arange(mb)
            )
            grads = jax.tree.map(lambda g: (g / mb), gsum)
            loss = lsum / mb
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, lr, gnorm = adamw.apply_updates(
            params, grads, opt_state, tcfg
        )
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Serving prefill: fill the KV cache, return only the last-position
    logits (what the next decode step consumes). XLA dead-code-eliminates the
    other S-1 head projections."""
    def prefill_step(params, batch):
        logits, cache = adapters.prefill_fn(params, batch, cfg)
        return logits[:, -1:], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One new token for every sequence in the batch, greedy-sampled."""
    def serve_step(params, cache, tokens):
        logits, cache = adapters.decode_fn(params, cache, tokens, cfg)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, cache

    return serve_step
