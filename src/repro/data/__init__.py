from repro.data.pipeline import DataConfig, batch_for_step, stream, documents_for_step
from repro.data.packing import pack_documents, packing_efficiency

__all__ = [
    "DataConfig",
    "batch_for_step",
    "stream",
    "documents_for_step",
    "pack_documents",
    "packing_efficiency",
]
