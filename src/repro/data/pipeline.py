"""Deterministic, shardable synthetic data pipeline.

Production framing: every (step, host) pair maps to a unique slice of an
infinite deterministic token stream, so (i) restarts resume exactly (the
checkpoint stores only the step), (ii) adding/removing hosts re-shards the
stream without replay bookkeeping (elastic scaling), (iii) no host ever
reads another host's slice (no coordination).

The "corpus" is a mixture of Zipf-distributed token documents with
power-law lengths — enough structure for the matching-based packer
(data/packing.py) to have real work to do.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_per_host: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    mean_doc_len: int = 512
    pack: bool = True


def _doc(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    length = int(np.clip(rng.pareto(1.5) * cfg.mean_doc_len * 0.5 + 16, 16, cfg.seq_len))
    # Zipf tokens (clipped to vocab)
    toks = rng.zipf(1.3, size=length)
    return np.clip(toks, 1, cfg.vocab_size - 1).astype(np.int32)


def documents_for_step(step: int, cfg: DataConfig, count: int) -> list:
    """Deterministic document batch for (step, host)."""
    seed = (cfg.seed * 1_000_003 + step) * 4099 + cfg.host_id
    rng = np.random.default_rng(seed)
    return [_doc(rng, cfg) for _ in range(count)]


def batch_for_step(step: int, cfg: DataConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (tokens [B, S], loss_mask [B, S]) for this host at `step`.

    With cfg.pack, documents are packed via the maximal-matching packer;
    otherwise each row is one truncated/padded document.
    """
    from repro.data.packing import pack_documents  # lazy: avoid jax at import

    docs = documents_for_step(step, cfg, cfg.batch_per_host * 2)
    if cfg.pack:
        rows, mask = pack_documents(docs, cfg.batch_per_host, cfg.seq_len)
    else:
        rows = np.zeros((cfg.batch_per_host, cfg.seq_len), np.int32)
        mask = np.zeros((cfg.batch_per_host, cfg.seq_len), bool)
        for i in range(cfg.batch_per_host):
            d = docs[i][: cfg.seq_len]
            rows[i, : len(d)] = d
            mask[i, : len(d)] = True
    return rows, mask


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield batch_for_step(step, cfg)
        step += 1
