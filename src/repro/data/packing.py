"""Matching-based sequence packing — the paper's technique in the data path.

Packing documents into fixed-length rows is a maximal-matching problem on the
compatibility graph: vertices = documents, edge (i, j) iff len_i + len_j <=
seq_len. A matched pair shares a row; unmatched documents get their own
(truncated) row. One single pass over the candidate edge stream — the Skipper
matcher from core/ — replaces the usual first-fit bin-packing loop, and its
output is provably maximal: no two leftover rows could have been merged.

Candidate edges are generated sorted by combined fill ratio (big+small first)
so the greedy pass approximates best-fit packing quality.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.skipper import skipper
from repro.graphs.types import EdgeList


def _candidate_edges(lengths: np.ndarray, seq_len: int, max_degree: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Pair candidates: sort by length, try to pair long docs with the best
    fitting short docs (two-pointer over the sorted order, widened to
    max_degree neighbors)."""
    order = np.argsort(lengths)
    n = len(lengths)
    us, vs = [], []
    for rank_i in range(n):
        i = order[rank_i]
        # candidates: the largest docs that still fit together with i
        remaining = seq_len - lengths[i]
        hi = np.searchsorted(lengths[order], remaining, side="right")
        for rank_j in range(max(0, hi - max_degree), hi):
            j = order[rank_j]
            if i < j and lengths[i] + lengths[j] <= seq_len:
                us.append(i)
                vs.append(j)
    if not us:
        return np.zeros((0,), np.int32), np.zeros((0,), np.int32)
    u = np.asarray(us, np.int32)
    v = np.asarray(vs, np.int32)
    fill = lengths[u] + lengths[v]
    best_first = np.argsort(-fill, kind="stable")
    return u[best_first], v[best_first]


def pack_documents(
    docs: List[np.ndarray], num_rows: int, seq_len: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack documents into [num_rows, seq_len] (tokens, loss_mask)."""
    lengths = np.asarray([len(d) for d in docs])
    u, v = _candidate_edges(lengths, seq_len)
    pairs: List[Tuple[int, ...]] = []
    used = np.zeros(len(docs), bool)
    if len(u):
        edges = EdgeList(jnp.asarray(u), jnp.asarray(v), len(docs))
        result, _ = skipper(edges, tile_size=256)
        mask = np.asarray(result.match_mask)
        for k in np.nonzero(mask)[0]:
            pairs.append((int(u[k]), int(v[k])))
            used[u[k]] = used[v[k]] = True
    singles = [i for i in range(len(docs)) if not used[i]]
    rows = np.zeros((num_rows, seq_len), np.int32)
    loss_mask = np.zeros((num_rows, seq_len), bool)
    slots = pairs + [(i,) for i in singles]
    for r in range(min(num_rows, len(slots))):
        cursor = 0
        for doc_id in slots[r]:
            d = docs[doc_id][: seq_len - cursor]
            rows[r, cursor : cursor + len(d)] = d
            loss_mask[r, cursor : cursor + len(d)] = True
            cursor += len(d)
    return rows, loss_mask


def packing_efficiency(loss_mask: np.ndarray) -> float:
    return float(loss_mask.mean())
