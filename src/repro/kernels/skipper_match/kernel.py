"""Pallas TPU kernel: windowed single-pass greedy matching (Skipper core).

TPU mapping of the paper's hot loop (Alg. 1 lines 5-18). The grid walks edge
tiles *sequentially per core* — TPU grid semantics — so the vertex-state
window can live in VMEM across grid steps (constant index_map + input/output
aliasing) and the algorithm is race-free by construction; the asynchrony of
the CPU original is re-introduced one level up (across cores/devices, see
core/distributed.py).

MXU/VPU mapping per tile of T edges over a W-vertex VMEM window:

  * state gather  : one_hot(u, W) @ state — an (T, W) x (W,) contraction; on
    TPU this hits the MXU instead of serializing into scalar loads. W is the
    BlockSpec-controlled VMEM working set (W * 4 B for the state vector plus
    the T x W one-hots).
  * JIT conflicts : the T x T triangular share matrix (VPU compares) — the
    vectorized analogue of "observe RSVD, wait a few cycles". Blocked edges
    retry in the next unrolled round, NOT in a later pass: single pass over
    edges is preserved.
  * state scatter : commit vector folded back with one_hot transpose matmuls;
    committed edges are mutually endpoint-disjoint by construction, so the
    scatter is conflict-free (the kernel-level linearization point).
  * fallback      : rare leftover chains resolved by a sequential fori_loop
    over the tile (scalar path) — bounded, in-VMEM, still same-pass.

Alignment: choose T a multiple of 8*128 lanes / pack (we default T=256) and
W a multiple of 128 so the one-hot matmuls are MXU-aligned.

States: ACC=0, MCHD=2 (int32 in VMEM; the at-rest array is uint8/vertex — the
paper's 1 B/vertex claim — converted at the ops.py boundary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACC = 0
MCHD = 2


def _one_hot(idx: jax.Array, width: int) -> jax.Array:
    """Mask-safe one-hot: idx < 0 maps to the zero row. 2-D iota (TPU needs
    >=2-D iota)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], width), 1)
    return (cols == idx[:, None]).astype(jnp.int32)


def skipper_window_kernel(
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
):
    """One grid step = one tile of T window-local edges.

    u_ref/v_ref: int32[T] window-local endpoint ids (-1 = padding).
    state_in_ref: int32[W] initial state (read at step 0 only).
    state_ref: int32[W] in/out VMEM-resident state window (aliased).
    matched_ref: int32[T] per-edge decision (1 = matched).
    conflicts_ref: int32[T] rounds spent blocked (Table II instrumentation).
    """
    t = u_ref.shape[0]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        state_ref[...] = state_in_ref[...]

    u = u_ref[...]
    v = v_ref[...]
    valid = (u >= 0) & (u != v)

    # one-hots are reused by every round: gather AND scatter operands.
    hu = _one_hot(jnp.where(valid, u, -1), window)  # [T, W]
    hv = _one_hot(jnp.where(valid, v, -1), window)

    # triangular endpoint-sharing matrix (the JIT-conflict detector)
    share = (
        (u[:, None] == u[None, :])
        | (u[:, None] == v[None, :])
        | (v[:, None] == u[None, :])
        | (v[:, None] == v[None, :])
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    lower = cols < rows
    conflict = share & lower & valid[None, :] & valid[:, None]

    matched = jnp.zeros((t,), jnp.bool_)
    conflicts = jnp.zeros((t,), jnp.int32)

    for _ in range(vector_rounds):
        state = state_ref[...]
        su = hu @ state  # MXU gather
        sv = hv @ state
        free = valid & (~matched) & (su == ACC) & (sv == ACC)
        blocked = jnp.any(conflict & free[None, :], axis=1) & free
        commit = free & ~blocked
        # conflict-free scatter: committed edges are endpoint-disjoint
        ci = commit.astype(jnp.int32)
        hit = (ci @ hu) + (ci @ hv)  # [W]
        state_ref[...] = jnp.where(hit > 0, MCHD, state)
        matched = matched | commit
        conflicts = conflicts + blocked.astype(jnp.int32)

    if fallback:
        # exact sequential cleanup of pathological chains (rare)
        state = state_ref[...]
        su = hu @ state
        sv = hv @ state
        remaining = valid & (~matched) & (su == ACC) & (sv == ACC)

        def body(i, carry):
            state, matched = carry
            rem_i = remaining[i]
            ui = u[i]
            vi = v[i]
            s_u = state[jnp.where(rem_i, ui, 0)]
            s_v = state[jnp.where(rem_i, vi, 0)]
            take = rem_i & (s_u == ACC) & (s_v == ACC)
            state = jnp.where(
                take,
                state.at[ui].set(MCHD).at[vi].set(MCHD),
                state,
            )
            matched = matched.at[i].set(matched[i] | take)
            return state, matched

        state, matched = jax.lax.fori_loop(0, t, body, (state, matched))
        state_ref[...] = state

    matched_ref[...] = matched.astype(jnp.int32)
    conflicts_ref[...] = conflicts


def build_window_matcher(
    num_tiles: int,
    tile_size: int,
    window: int,
    vector_rounds: int = 3,
    fallback: bool = True,
    interpret: bool = True,
):
    """Construct the pallas_call for a (num_tiles x tile_size) edge stream
    over a ``window``-vertex state window."""
    kernel = functools.partial(
        skipper_window_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
    )
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # u tiles
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # v tiles
            pl.BlockSpec((window,), lambda i: (0,)),          # initial state
        ],
        out_specs=[
            pl.BlockSpec((window,), lambda i: (0,)),          # state (resident)
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # matched
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # conflicts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((window,), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), jnp.int32),
        ],
        interpret=interpret,
    )
