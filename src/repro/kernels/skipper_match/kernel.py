"""Pallas TPU kernel: windowed single-pass greedy matching (Skipper core).

TPU mapping of the paper's hot loop (Alg. 1 lines 5-18). Two entry points:

* ``build_window_matcher``   — 1-D grid over the tiles of ONE vertex window
  (the unit-test / debugging surface).
* ``build_pipeline_matcher`` — 2-D grid ``(window, tile)`` over the WHOLE
  graph's window schedule (``graphs/windows.py``). The state BlockSpec index
  map depends only on the window coordinate, so the W-vertex state block
  stays resident in VMEM across all tile steps of a window and is swapped
  (written back to HBM, next block DMA'd in) exactly once per window — zero
  host round-trips for the full graph. TPU grids iterate the LAST dimension
  innermost, which is what makes the residency work.

Both wrap the same per-tile body. The first-claim decision logic (conflict
matrix + commit rule) is ``core/engine.py`` — shared verbatim with the jnp
matchers so the invariant cannot drift; only the gather/scatter is
kernel-specific:

  * state gather  : one_hot(u, W) @ state — a (T, W) x (W,) contraction; on
    TPU this hits the MXU instead of serializing into scalar loads. W is the
    BlockSpec-controlled VMEM working set (W * 4 B for the state vector plus
    the T x W one-hots).
  * JIT conflicts : the T x T triangular share matrix (VPU compares) — the
    vectorized analogue of "observe RSVD, wait a few cycles". Blocked edges
    retry in the next unrolled round, NOT in a later pass: single pass over
    edges is preserved.
  * state scatter : commit vector folded back with one_hot transpose matmuls;
    committed edges are mutually endpoint-disjoint by construction, so the
    scatter is conflict-free (the kernel-level linearization point).
  * fallback      : rare leftover chains resolved by a sequential fori_loop
    over the tile (scalar path) — bounded, in-VMEM, still same-pass.

Alignment: choose T a multiple of 8*128 lanes / pack (we default T=256) and
W a multiple of 128 so the one-hot matmuls are MXU-aligned.

States: ACC=0, MCHD=2 (int32 in VMEM; the at-rest array is uint8/vertex — the
paper's 1 B/vertex claim — converted at the ops.py boundary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import engine
from repro.core.engine import ACC, MCHD


def _one_hot(idx: jax.Array, width: int) -> jax.Array:
    """Mask-safe one-hot: idx < 0 maps to the zero row. 2-D iota (TPU needs
    >=2-D iota)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], width), 1)
    return (cols == idx[:, None]).astype(jnp.int32)


def _match_tile(u, v, state_ref, *, vector_rounds: int, window: int, fallback: bool):
    """Run one tile of T window-local edges against the VMEM-resident state.

    Writes committed MCHDs into ``state_ref`` round by round; returns
    (matched bool[T], conflicts int32[T])."""
    valid = (u >= 0) & (u != v)

    # one-hots are reused by every round: gather AND scatter operands.
    hu = _one_hot(jnp.where(valid, u, -1), window)  # [T, W]
    hv = _one_hot(jnp.where(valid, v, -1), window)

    def read_state():
        state = state_ref[...]
        return hu @ state, hv @ state  # MXU gathers

    def apply_commits(commit):
        # conflict-free scatter: committed edges are endpoint-disjoint
        ci = commit.astype(jnp.int32)
        hit = (ci @ hu) + (ci @ hv)  # [W]
        state_ref[...] = jnp.where(hit > 0, MCHD, state_ref[...])

    matched, conflicts = engine.run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds
    )

    if fallback:
        # exact sequential cleanup of pathological chains (rare)
        t = u.shape[0]
        state = state_ref[...]
        su = hu @ state
        sv = hv @ state
        remaining = valid & (~matched) & (su == ACC) & (sv == ACC)

        def body(i, carry):
            state, matched = carry
            rem_i = remaining[i]
            ui = u[i]
            vi = v[i]
            s_u = state[jnp.where(rem_i, ui, 0)]
            s_v = state[jnp.where(rem_i, vi, 0)]
            take = rem_i & (s_u == ACC) & (s_v == ACC)
            state = jnp.where(
                take,
                state.at[ui].set(MCHD).at[vi].set(MCHD),
                state,
            )
            matched = matched.at[i].set(matched[i] | take)
            return state, matched

        state, matched = jax.lax.fori_loop(0, t, body, (state, matched))
        state_ref[...] = state

    return matched, conflicts


def skipper_window_kernel(
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
):
    """One grid step = one tile of T window-local edges (1-D grid, one window).

    u_ref/v_ref: int32[T] window-local endpoint ids (-1 = padding).
    state_in_ref: int32[W] initial state (read at step 0 only).
    state_ref: int32[W] in/out VMEM-resident state window (aliased).
    matched_ref: int32[T] per-edge decision (1 = matched).
    conflicts_ref: int32[T] rounds spent blocked (Table II instrumentation).
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        state_ref[...] = state_in_ref[...]

    matched, conflicts = _match_tile(
        u_ref[...], v_ref[...], state_ref,
        vector_rounds=vector_rounds, window=window, fallback=fallback,
    )
    matched_ref[...] = matched.astype(jnp.int32)
    conflicts_ref[...] = conflicts


def skipper_pipeline_kernel(
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
):
    """One grid step = (window w, tile t). Blocks carry a leading length-1
    window axis; the state block is swapped per *window*, not per step, so it
    is initialized when t == 0 and stays VMEM-resident for all tiles of w."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = state_in_ref[...]

    # views over the [W]-vector / [T]-vector payloads of the (1, ·) blocks
    class _Row:
        """[W]-vector view of the (1, W) state block (keeps _match_tile 1-D)."""

        def __getitem__(self, _):
            return state_ref[0, :]

        def __setitem__(self, _, value):
            state_ref[0, :] = value

    matched, conflicts = _match_tile(
        u_ref[0, :], v_ref[0, :], _Row(),
        vector_rounds=vector_rounds, window=window, fallback=fallback,
    )
    matched_ref[0, :] = matched.astype(jnp.int32)
    conflicts_ref[0, :] = conflicts


def build_window_matcher(
    num_tiles: int,
    tile_size: int,
    window: int,
    vector_rounds: int = 3,
    fallback: bool = True,
    interpret: bool = True,
):
    """Construct the pallas_call for a (num_tiles x tile_size) edge stream
    over a single ``window``-vertex state window."""
    kernel = functools.partial(
        skipper_window_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
    )
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # u tiles
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # v tiles
            pl.BlockSpec((window,), lambda i: (0,)),          # initial state
        ],
        out_specs=[
            pl.BlockSpec((window,), lambda i: (0,)),          # state (resident)
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # matched
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # conflicts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((window,), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), jnp.int32),
        ],
        interpret=interpret,
    )


def build_pipeline_matcher(
    num_windows: int,
    tiles_per_window: int,
    tile_size: int,
    window: int,
    vector_rounds: int = 3,
    fallback: bool = True,
    interpret: bool = True,
):
    """Construct ONE pallas_call covering every (window, tile) of the graph's
    schedule.

    Inputs: u/v int32[num_windows, tiles_per_window * tile_size] window-local
    ids, state0 int32[num_windows, window]. Outputs: (state, matched,
    conflicts) with the same layouts. The state index map ``(w, t) -> (w, 0)``
    ignores t: the revolving VMEM block is written back only when w changes —
    one HBM round-trip per window, zero host round-trips.
    """
    kernel = functools.partial(
        skipper_pipeline_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
    )
    slots = tiles_per_window * tile_size
    return pl.pallas_call(
        kernel,
        grid=(num_windows, tiles_per_window),
        in_specs=[
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # u tiles
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # v tiles
            pl.BlockSpec((1, window), lambda w, t: (w, 0)),      # initial state
        ],
        out_specs=[
            pl.BlockSpec((1, window), lambda w, t: (w, 0)),      # state (resident per window)
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # matched
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # conflicts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_windows, window), jnp.int32),
            jax.ShapeDtypeStruct((num_windows, slots), jnp.int32),
            jax.ShapeDtypeStruct((num_windows, slots), jnp.int32),
        ],
        interpret=interpret,
    )
