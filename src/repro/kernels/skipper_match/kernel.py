"""Pallas TPU kernel: windowed single-pass greedy matching (Skipper core).

TPU mapping of the paper's hot loop (Alg. 1 lines 5-18). Three entry points:

* ``build_window_matcher``   — 1-D grid over the tiles of ONE vertex window
  (the unit-test / debugging surface).
* ``build_pipeline_matcher`` — 2-D grid ``(row, tile)`` over the dense tier
  of the graph's window schedule (``graphs/windows.py``; a row is a dense
  window). The state BlockSpec index map depends only on the row coordinate,
  so the W-vertex state block stays resident in VMEM across all tile steps
  of a window and is swapped (written back to HBM, next block DMA'd in)
  exactly once per window — zero host round-trips for the full graph. TPU
  grids iterate the LAST dimension innermost, which is what makes the
  residency work.
* ``build_boundary_matcher`` — scalar-prefetch 1-D grid over the global-tier
  tiles (cross-window + coalesced sparse-window edges), block-pair grouped
  by the host schedule (``graphs/windows.py``; DESIGN.md §10): each grid
  step DMAs only the TWO ``window``-sized state blocks its pair touches
  into a (2, W) VMEM scratch — O(window) VMEM, independent of V — and the
  pair tile is ``engine.tile_pass_pair``'s concatenated-state tile, so the
  jnp reference epilogue stays bit-identical by construction.

Both wrap the same per-tile body. The first-claim decision logic (conflict
matrix + commit rule) is ``core/engine.py`` — shared verbatim with the jnp
matchers so the invariant cannot drift; only the gather/scatter is
kernel-specific:

  * state gather  : one_hot(u, W) @ state — a (T, W) x (W,) contraction; on
    TPU this hits the MXU instead of serializing into scalar loads. W is the
    BlockSpec-controlled VMEM working set (W * spec.vmem_bytes for the state
    vector — 1 B/vertex under the default spec — plus the T x W one-hots).
    The int32 one-hot operand widens the narrow state to i32 *inside* the
    contraction (jax promotion), which is exactly where the MXU wants it;
    the scatter's ``where`` narrows straight back to the state dtype.
  * JIT conflicts : the T x T triangular share matrix (VPU compares) — the
    vectorized analogue of "observe RSVD, wait a few cycles". Blocked edges
    retry in the next unrolled round, NOT in a later pass: single pass over
    edges is preserved.
  * state scatter : commit vector folded back with one_hot transpose matmuls;
    committed edges are mutually endpoint-disjoint by construction, so the
    scatter is conflict-free (the kernel-level linearization point).
  * fallback      : rare leftover chains resolved by iterated first-claim
    rounds to fixpoint (``engine.greedy_fallback_rounds`` — exactly the
    sequential greedy's result), all VPU/MXU work, in-VMEM, still same-pass.

Alignment: choose T a multiple of 8*128 lanes / pack (we default T=256) and
W a multiple of 128 so the one-hot matmuls are MXU-aligned.

States: ACC=0, MCHD=2. Every width (VMEM state, matched/conflicts outputs)
comes from the builder's ``StateSpec`` (``core/statespec.py``); the default
spec keeps the paper's 1 B/vertex claim honest in VMEM too, the
``legacy_i32`` spec compiles the historical all-i32 graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import engine
from repro.core.engine import MCHD
from repro.core.statespec import DEFAULT, StateSpec


def _one_hot(idx: jax.Array, width: int) -> jax.Array:
    """Mask-safe one-hot: idx < 0 maps to the zero row. 2-D iota (TPU needs
    >=2-D iota)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], width), 1)
    return (cols == idx[:, None]).astype(jnp.int32)


def _match_tile(u, v, state_ref, *, vector_rounds: int, window: int, fallback: bool):
    """Run one tile of T window-local edges against the VMEM-resident state.

    Writes committed MCHDs into ``state_ref`` round by round; returns
    (matched bool[T], conflicts int32[T])."""
    valid = (u >= 0) & (u != v)
    # matrix blocked-impl: T x T VPU compares are native here, and Mosaic
    # has no sort for the claim-sort twin (engine docstring) — same function.
    blocked_fn = engine.blocked_from_matrix(engine.share_matrix(u, v, valid))

    # one-hots are reused by every round: gather AND scatter operands.
    hu = _one_hot(jnp.where(valid, u, -1), window)  # [T, W]
    hv = _one_hot(jnp.where(valid, v, -1), window)

    def gather(state):
        return hu @ state, hv @ state  # MXU gathers

    def scatter(state, commit):
        # conflict-free scatter: committed edges are endpoint-disjoint
        ci = commit.astype(jnp.int32)
        hit = (ci @ hu) + (ci @ hv)  # [W]
        return jnp.where(hit > 0, MCHD, state)

    def read_state():
        return gather(state_ref[...])

    def apply_commits(commit):
        state_ref[...] = scatter(state_ref[...], commit)

    matched, conflicts = engine.run_first_claim_rounds(
        u, v, valid, read_state, apply_commits, vector_rounds, blocked_fn
    )

    if fallback:
        # exact vectorized cleanup of pathological chains (rare): iterated
        # first-claim rounds to fixpoint == the sequential index-order greedy
        # (engine.greedy_fallback_rounds), all VPU/MXU work — no scalar loop.
        state, matched, _taken = engine.greedy_fallback_rounds(
            state_ref[...], u, v, valid, matched, blocked_fn,
            gather=gather, scatter=scatter,
        )
        state_ref[...] = state

    return matched, conflicts


def skipper_window_kernel(
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
    spec: StateSpec = DEFAULT,
):
    """One grid step = one tile of T window-local edges (1-D grid, one window).

    u_ref/v_ref: int32[T] window-local endpoint ids (-1 = padding).
    state_in_ref: spec.vmem[W] initial state (read at step 0 only).
    state_ref: spec.vmem[W] in/out VMEM-resident state window (aliased).
    matched_ref: spec.counter[T] per-edge decision (1 = matched).
    conflicts_ref: spec.counter[T] rounds spent blocked (Table II
    instrumentation; conflicts <= vector_rounds, so the narrow store is
    exact — guarded by ``spec.validate_rounds`` at build time).
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        state_ref[...] = state_in_ref[...]

    matched, conflicts = _match_tile(
        u_ref[...], v_ref[...], state_ref,
        vector_rounds=vector_rounds, window=window, fallback=fallback,
    )
    matched_ref[...] = matched.astype(spec.counter_dtype)
    conflicts_ref[...] = conflicts.astype(spec.counter_dtype)


def skipper_pipeline_kernel(
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
    spec: StateSpec = DEFAULT,
):
    """One grid step = (window w, tile t). Blocks carry a leading length-1
    window axis; the state block is swapped per *window*, not per step, so it
    is initialized when t == 0 and stays VMEM-resident for all tiles of w.
    The block dtype is ``spec.vmem`` — window * spec.vmem_bytes resident
    bytes per step."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = state_in_ref[...]

    # [W]-vector view of the (1, W) state block (keeps _match_tile 1-D)
    def _set_row(value):
        state_ref[0, :] = value

    row = engine.StateCell(get=lambda: state_ref[0, :], set=_set_row)

    matched, conflicts = _match_tile(
        u_ref[0, :], v_ref[0, :], row,
        vector_rounds=vector_rounds, window=window, fallback=fallback,
    )
    matched_ref[0, :] = matched.astype(spec.counter_dtype)
    conflicts_ref[0, :] = conflicts.astype(spec.counter_dtype)


def skipper_boundary_kernel(
    blk_u_ref,
    blk_v_ref,
    u_ref,
    v_ref,
    state_in_ref,
    state_ref,
    matched_ref,
    conflicts_ref,
    pair_ref,
    sem_u,
    sem_v,
    *,
    vector_rounds: int,
    window: int,
    fallback: bool,
    spec: StateSpec = DEFAULT,
):
    """One grid step = one tile of T global-tier edges, all sharing ONE
    (window-block of u, window-block of v) pair — the host schedule groups
    the stream so this holds by construction (``graphs/windows.py``,
    DESIGN.md §10).

    blk_u_ref/blk_v_ref are the scalar-prefetch per-tile block ids; the full
    [num_windows, window] state lives in ANY memory (HBM), aliased in/out,
    and each step manually DMAs the pair's two state rows into the (2, W)
    VMEM ``pair_ref`` scratch. Edge ids are OFFSET-LOCAL: u in [0, W), v in
    [W, 2W) for cross-block pairs and [0, W) for same-block pairs, so the
    scratch viewed as a flat [2W] vector is exactly the concatenated state of
    ``engine.tile_pass_pair`` — the jnp reference epilogue is bit-identical
    by construction, and the gather/scatter are one-hot matmuls like the
    windowed kernel (no dynamic fancy indexing: this is what un-blocks real
    Mosaic lowering, the former ROADMAP caveat).

    Aliasing contract: writes go back v-row first, u-row second, both before
    the step ends (DMA waits serialize them), so a later pair (b, c) reads
    the commits of an earlier pair (a, b), and same-block pairs — which load
    only the u row and leave the v half of the scratch untouched — store the
    u row last so it wins unconditionally.

    VMEM per grid step: 2 * window * spec.vmem_bytes of state + the T x (2W)
    one-hots + the T x T share matrix — O(window + tile^2), independent of V.
    """
    i = pl.program_id(0)
    bu = blk_u_ref[i]
    bv = blk_v_ref[i]

    cp_u = pltpu.make_async_copy(state_ref.at[bu], pair_ref.at[0], sem_u)
    cp_u.start()
    cp_u.wait()

    @pl.when(bv != bu)
    def _load_v():
        cp = pltpu.make_async_copy(state_ref.at[bv], pair_ref.at[1], sem_v)
        cp.start()
        cp.wait()

    # flat [2W] view of the scratch = tile_pass_pair's concatenated state
    def _set_pair(value):
        pair_ref[...] = value.reshape(2, window)

    cell = engine.StateCell(
        get=lambda: pair_ref[...].reshape(2 * window), set=_set_pair
    )

    matched, conflicts = _match_tile(
        u_ref[0, :], v_ref[0, :], cell,
        vector_rounds=vector_rounds, window=2 * window, fallback=fallback,
    )
    matched_ref[0, :] = matched.astype(spec.counter_dtype)
    conflicts_ref[0, :] = conflicts.astype(spec.counter_dtype)

    # write-back: v row first, u row second (same-block pairs skip v and the
    # u row — the only row touched — lands last; see tile_pass_pair)
    @pl.when(bv != bu)
    def _store_v():
        cp = pltpu.make_async_copy(pair_ref.at[1], state_ref.at[bv], sem_v)
        cp.start()
        cp.wait()

    cp_u2 = pltpu.make_async_copy(pair_ref.at[0], state_ref.at[bu], sem_u)
    cp_u2.start()
    cp_u2.wait()


@functools.lru_cache(maxsize=None)
def build_boundary_matcher(
    num_tiles: int,
    tile_size: int,
    num_windows: int,
    window: int,
    vector_rounds: int = 1,
    fallback: bool = True,
    interpret: bool = True,
    spec: StateSpec = DEFAULT,
):
    """Construct the scalar-prefetch pallas_call resolving the block-pair
    grouped global-tier stream.

    Call as ``fn(blk_u, blk_v, u, v, state)`` with blk_u/blk_v
    int32[num_tiles] pair block ids (scalar-prefetched), u/v
    int32[num_tiles, tile_size] OFFSET-LOCAL ids (-1 padding), and state
    spec.vmem[num_windows, window] (aliased in/out — the caller's buffer is
    donated, so its dtype must match the spec). Returns (state, matched,
    conflicts) with matched/conflicts shaped spec.counter[num_tiles,
    tile_size]. Cached per static shape+spec so repeated driver calls reuse
    one pallas_call (and one trace)."""
    spec.validate_rounds(vector_rounds)
    kernel = functools.partial(
        skipper_boundary_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
        spec=spec,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),  # u tiles
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),  # v tiles
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),     # state
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),     # state
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),  # matched
            pl.BlockSpec((1, tile_size), lambda i, bu, bv: (i, 0)),  # conflicts
        ],
        scratch_shapes=[
            pltpu.VMEM((2, window), spec.vmem_dtype),  # the pair's state rows
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_windows, window), spec.vmem_dtype),
            jax.ShapeDtypeStruct((num_tiles, tile_size), spec.counter_dtype),
            jax.ShapeDtypeStruct((num_tiles, tile_size), spec.counter_dtype),
        ],
        # state input (after the 2 prefetch scalars + u + v) -> state output
        input_output_aliases={4: 0},
        interpret=interpret,
    )


@functools.lru_cache(maxsize=None)
def build_window_matcher(
    num_tiles: int,
    tile_size: int,
    window: int,
    vector_rounds: int = 1,
    fallback: bool = True,
    interpret: bool = True,
    spec: StateSpec = DEFAULT,
):
    """Construct the pallas_call for a (num_tiles x tile_size) edge stream
    over a single ``window``-vertex state window (state in ``spec.vmem``,
    matched/conflicts in ``spec.counter``)."""
    spec.validate_rounds(vector_rounds)
    kernel = functools.partial(
        skipper_window_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
        spec=spec,
    )
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # u tiles
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # v tiles
            pl.BlockSpec((window,), lambda i: (0,)),          # initial state
        ],
        out_specs=[
            pl.BlockSpec((window,), lambda i: (0,)),          # state (resident)
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # matched
            pl.BlockSpec((tile_size,), lambda i: (i,)),       # conflicts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((window,), spec.vmem_dtype),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), spec.counter_dtype),
            jax.ShapeDtypeStruct((num_tiles * tile_size,), spec.counter_dtype),
        ],
        interpret=interpret,
    )


@functools.lru_cache(maxsize=None)
def build_pipeline_matcher(
    num_windows: int,
    tiles_per_window: int,
    tile_size: int,
    window: int,
    vector_rounds: int = 1,
    fallback: bool = True,
    interpret: bool = True,
    spec: StateSpec = DEFAULT,
):
    """Construct ONE pallas_call covering every (window, tile) of the graph's
    schedule.

    Inputs: u/v int32[num_windows, tiles_per_window * tile_size] window-local
    ids, state0 spec.vmem[num_windows, window]. Outputs: (state, matched,
    conflicts) — state in spec.vmem, matched/conflicts in spec.counter. The
    state index map ``(w, t) -> (w, 0)`` ignores t: the revolving VMEM block
    is written back only when w changes — one HBM round-trip per window, zero
    host round-trips.
    """
    spec.validate_rounds(vector_rounds)
    kernel = functools.partial(
        skipper_pipeline_kernel,
        vector_rounds=vector_rounds,
        window=window,
        fallback=fallback,
        spec=spec,
    )
    slots = tiles_per_window * tile_size
    return pl.pallas_call(
        kernel,
        grid=(num_windows, tiles_per_window),
        in_specs=[
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # u tiles
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # v tiles
            pl.BlockSpec((1, window), lambda w, t: (w, 0)),      # initial state
        ],
        out_specs=[
            pl.BlockSpec((1, window), lambda w, t: (w, 0)),      # state (resident per window)
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # matched
            pl.BlockSpec((1, tile_size), lambda w, t: (w, t)),   # conflicts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_windows, window), spec.vmem_dtype),
            jax.ShapeDtypeStruct((num_windows, slots), spec.counter_dtype),
            jax.ShapeDtypeStruct((num_windows, slots), spec.counter_dtype),
        ],
        interpret=interpret,
    )
