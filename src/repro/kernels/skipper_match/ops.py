"""Jit'd wrappers around the skipper_match Pallas kernels.

``skipper_match_window`` — raw windowed matcher (edges already window-local).
``skipper_match``        — full-graph driver, device-resident: a one-shot host
    precompute (``graphs/windows.build_window_schedule``, optionally behind a
    ``reorder=`` locality renumbering) packs the canonical edge stream into a
    static two-tier ``[num_rows, tiles_per_window, tile_size]`` schedule,
    then ONE traced function covers the whole graph: a single ``pallas_call``
    over the 2-D (row, tile) grid of dense windows — the vertex-state block
    revolves through VMEM per window, no host round-trips — followed by an
    in-device first-claim epilogue (a second, scalar-prefetch Pallas kernel
    streaming only the TWO window-sized state blocks each block-pair tile
    touches; ``engine.tile_pass_pair`` scan on the xla twin) that resolves
    the block-pair grouped global tier (cross-window + coalesced
    sparse-window edges). Every edge is still decided exactly once;
    Counters are computed on device; mask/conflicts/state come back in
    original stream order / vertex ids even when the schedule is reordered.

``interpret`` is a debug flag: ``None`` (default) resolves to False on TPU
(compiled Mosaic) and True elsewhere (Pallas' interpreter is the only Pallas
path on CPU). ``backend="xla"`` selects the jnp twin of the same schedule —
one compilation unit, identical semantics — which is what CPU benchmarks time
(see benchmarks/kernel_bench.py).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import functools

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.faults import (
    CORRUPT,
    FaultPlan,
    RecoveryReport,
    corruption_mask,
    detect_residual,
    proposal_drop_mask,
    residual_replay,
)
from repro.core.statespec import DEFAULT, StateSpec, resolve as resolve_spec
from repro.core.types import Counters, MatchResult
from repro.core.validate import check_matching
from repro.graphs.types import EdgeList
from repro.graphs.windows import WindowSchedule, build_window_schedule
from repro.kernels.skipper_match.kernel import (
    build_boundary_matcher,
    build_window_matcher,
)

# Incremented at TRACE time inside the pipeline body: the number of actual
# compilations of the full-graph pipeline. Tests use it to prove the driver
# performs zero per-window host round-trips (one trace covers all windows).
_PIPELINE_TRACES = 0


def pipeline_trace_count() -> int:
    return _PIPELINE_TRACES


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def skipper_match_window(
    u: jax.Array,
    v: jax.Array,
    state0: jax.Array,
    tile_size: int = 256,
    vector_rounds: int = 1,
    fallback: bool = True,
    interpret: Optional[bool] = None,
    spec: Optional[StateSpec] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Match a window-local edge stream. u/v: int32[M] (padded to tile
    multiple with -1), state0: [W] (coerced to ``spec.vmem``). Returns
    (state, matched, conflicts) in spec.vmem / spec.counter widths.
    """
    spec = resolve_spec(spec)
    if interpret is None:
        interpret = _auto_interpret()
    m = u.shape[0]
    pad = (-m) % tile_size
    if pad:
        u = jnp.concatenate([u, jnp.full((pad,), -1, jnp.int32)])
        v = jnp.concatenate([v, jnp.full((pad,), -1, jnp.int32)])
    num_tiles = u.shape[0] // tile_size
    window = state0.shape[0]
    call = build_window_matcher(
        num_tiles, tile_size, window, vector_rounds, fallback, interpret,
        spec,
    )
    state, matched, conflicts = call(u, v, state0.astype(spec.vmem_dtype))
    return state, matched[:m], conflicts[:m]


@functools.lru_cache(maxsize=64)
def _build_pipeline(
    num_windows: int,
    num_rows: int,
    tiles_per_window: int,
    tile_size: int,
    window: int,
    num_boundary_padded: int,
    num_edges: int,
    num_vertices: int,
    vector_rounds: int,
    interpret: bool,
    backend: str,
    conflict_method: str,
    faults: Optional[FaultPlan] = None,
    spec: StateSpec = DEFAULT,
):
    """One jitted compilation unit per static schedule shape: windowed kernel
    sweep over the dense rows + boundary epilogue + on-device counters.

    ``row_ids`` maps schedule rows to window ids (two-tier compaction);
    ``perm`` maps original vertex ids to renumbered ids (identity when the
    schedule was built without reordering) — the returned state is gathered
    through it so callers always see original ids.

    ``faults`` (frozen, part of the lru key; default None == zero extra ops)
    injects the single-device analogues of the distributed failure sites at
    the SAME stream positions / state cells (DESIGN.md §11): drop global-tier
    slots before the epilogue, lose one window row's tier contribution,
    corrupt assembled-state bytes.
    """
    n_flat = num_windows * window
    nb_tiles = num_boundary_padded // tile_size
    m = num_edges

    def pipeline(u2, v2, src, blk_u, blk_v, bu, bv, row_ids, perm):
        global _PIPELINE_TRACES
        _PIPELINE_TRACES += 1  # trace-time side effect (compilation counter)

        # window tier: the engine entry point shared with the distributed
        # matcher's per-device LOCAL PASS (pallas kernel / jnp twin).
        state2, matched2, conf2 = engine.window_tier_pass(
            u2, v2,
            window=window,
            tiles_per_window=tiles_per_window,
            tile_size=tile_size,
            vector_rounds=vector_rounds,
            backend=backend,
            interpret=interpret,
            spec=spec,
        )
        if faults is not None and faults.lose_shard is not None and num_rows:
            # FAULT: lost-shard analogue — one window row's tier
            # contribution (state AND matched bits) vanishes
            lost_row = faults.lose_shard % num_rows
            rowsel = (
                jax.lax.broadcasted_iota(jnp.int32, state2.shape, 0)
                == lost_row
            )
            state2 = jnp.where(rowsel, jnp.zeros_like(state2), state2)
            matched2 = jnp.where(
                jax.lax.broadcasted_iota(jnp.int32, matched2.shape, 0)
                == lost_row,
                jnp.zeros_like(matched2),
                matched2,
            )

        # Rows hold only the dense windows: scatter them into the full
        # [num_windows, window] state (coalesced windows stay all-ACC — their
        # edges are decided by the epilogue below) at the spec's kernel-tier
        # width: both backends carry spec.vmem here, so the Pallas boundary
        # kernel's aliased ANY-memory state and the xla twin's scan carry
        # are the same buffer layout (1 B/vertex under the default spec).
        state_dt = spec.vmem_dtype
        flat = (
            jnp.zeros((num_windows, window), state_dt)
            .at[row_ids].set(state2.astype(state_dt))
        )
        if faults is not None and faults.corrupt_state > 0.0:
            # FAULT: out-of-domain bytes in the assembled committed state —
            # same cells (renumbered-flat id space) as the distributed
            # locality-sharded injection
            flat = jnp.where(
                corruption_mask(faults, n_flat).reshape(num_windows, window),
                jnp.asarray(CORRUPT, state_dt),
                flat,
            )
        if faults is not None and faults.drop_proposals > 0.0 and nb_tiles:
            # FAULT: dropped global-tier slots — mark them invalid before
            # the epilogue so the edge is silently never decided (same
            # victims as the distributed gather-drop: the mask is keyed by
            # boundary stream position)
            dmask = proposal_drop_mask(faults, num_boundary_padded)
            bu = jnp.where(dmask, -1, bu)
            bv = jnp.where(dmask, -1, bv)

        # Global-tier epilogue: the block-pair grouped cross-window +
        # coalesced edges, same first-claim tile pass, still inside this
        # trace. On the pallas path this is the second kernel of the
        # compilation unit — a scalar-prefetch grid that DMAs only the two
        # state rows each pair tile touches (O(window) VMEM, DESIGN.md §10);
        # the xla twin runs the bit-identical tile_pass_pair scan over the
        # same offset-local tiles.
        if nb_tiles:
            but = bu.reshape(nb_tiles, tile_size)
            bvt = bv.reshape(nb_tiles, tile_size)
            if backend == "pallas":
                bcall = build_boundary_matcher(
                    nb_tiles, tile_size, num_windows, window,
                    vector_rounds, True, interpret, spec,
                )
                flat, bmt, bcf = bcall(blk_u, blk_v, but, bvt, flat)
            else:

                def bstep(rows, xs):
                    uloc, vloc, pbu, pbv = xs
                    rows, mt, cf, _fb = engine.tile_pass_pair(
                        rows, uloc, vloc, pbu, pbv, window=window,
                        vector_rounds=vector_rounds,
                        conflict_method=conflict_method, spec=spec,
                    )
                    return rows, (mt, cf)

                flat, (bmt, bcf) = jax.lax.scan(
                    bstep, flat, (but, bvt, blk_u, blk_v)
                )

        # Gather slot-order decisions back to stream order through the
        # host-precomputed map (``WindowSchedule.stream_src``): decision
        # slot layout is [windowed ++ global-tier ++ one zero pad slot].
        # A gather, not a scatter — a |E|-index scatter costs ~100x more on
        # CPU XLA and the map is static per schedule.
        cdt = spec.counter_dtype
        dec = [matched2.reshape(-1)]
        cfs = [conf2.reshape(-1)]
        if nb_tiles:
            dec.append(bmt.reshape(-1).astype(cdt))
            cfs.append(bcf.reshape(-1).astype(cdt))
        dec.append(jnp.zeros((1,), cdt))
        cfs.append(jnp.zeros((1,), cdt))
        mask = jnp.concatenate(dec)[src] > 0
        # per-edge conflicts stay i32 at the public boundary (callers sum
        # them into Counters); the narrow width is the O(E) buffer inside
        conf = jnp.concatenate(cfs)[src].astype(jnp.int32)

        nmatch = jnp.sum(mask).astype(jnp.int32)
        nconf = jnp.sum(conf).astype(jnp.int32)
        counters = Counters(
            edge_reads=jnp.asarray(m, jnp.int32),
            state_loads=jnp.asarray(2 * m, jnp.int32) + 2 * nconf,
            state_stores=2 * nmatch,
            rounds=jnp.asarray(1, jnp.int32),
        )
        # back to ORIGINAL vertex ids: original vertex i lives at renumbered
        # slot perm[i] of the flattened state (perm = arange when unordered).
        state_out = flat.reshape(n_flat)[perm].astype(spec.at_rest_dtype)
        return mask, state_out, conf, counters

    return jax.jit(pipeline)


def skipper_match(
    edges: Optional[EdgeList] = None,
    window: int = 2048,
    tile_size: int = 256,
    vector_rounds: int = 1,
    interpret: Optional[bool] = None,
    backend: str = "pallas",
    schedule: Optional[WindowSchedule] = None,
    dispersed: bool = True,
    reorder: str = "none",
    with_conflicts: bool = False,
    conflict_method: str = "auto",
    faults: Optional[FaultPlan] = None,
    on_fault: str = "raise",
    verify: bool = False,
    spec: Optional[StateSpec] = None,
) -> Union[MatchResult, Tuple]:
    """Full-graph device-resident matcher: one traced pipeline for all
    windows plus the in-device boundary epilogue.

    Pass ``schedule`` (from ``build_window_schedule``) to skip the host
    precompute — e.g. when timing the compiled device path; ``window`` /
    ``tile_size`` / ``dispersed`` / ``reorder`` are then taken from the
    schedule. ``reorder`` selects a locality renumbering policy
    (``graphs/reorder.py``); results — mask, conflicts AND state — are
    always in the original edge-stream order / vertex ids regardless.
    ``conflict_method`` reaches the XLA twin's boundary-epilogue
    ``engine.tile_pass`` (the Pallas kernels force the share-matrix form —
    Mosaic has no sort/scatter); the choice never changes output.

    ``spec`` (a frozen :class:`StateSpec`, ``None`` -> the uint8 default)
    picks the vertex-state width of every tier — VMEM blocks, the boundary
    kernel's ANY-memory state, the matched/conflicts buffers, the returned
    at-rest state. ``StateSpec.legacy_i32()`` compiles the historical
    all-i32 graph; matchings are bit-identical across specs (test-pinned).

    Failure handling (DESIGN.md §11): ``faults=`` threads a frozen
    :class:`FaultPlan` into the compiled pipeline (``None``, the default,
    compiles the exact pre-harness graph). ``on_fault`` decides what to do
    about damage — the single-device pipeline has no runtime tripwire
    (nothing overflows), so ``"raise"`` only has teeth with ``verify=True``:

    * ``"raise"`` (default): return the result as-is; with ``verify=True``
      raise ``RuntimeError`` if the matching fails ``check_matching`` or
      residual/corrupted damage is detected.
    * ``"report"``: append a :class:`RecoveryReport` (detection only) to
      the return tuple. Needs ``edges``.
    * ``"recover"``: run the residual replay (``faults.residual_replay`` —
      rebuild state from the mask, complete the matching over undecided
      edges); the result is provably valid+maximal on the uncorrupted
      graph. Appends the :class:`RecoveryReport`. Needs ``edges``.
      ``Counters`` still describe the faulted run, not the replay.

    Return value order: ``result`` [, ``conflicts`` if ``with_conflicts``]
    [, ``report`` if ``on_fault != "raise"``].
    """
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown backend {backend!r}")
    if on_fault not in ("raise", "recover", "report"):
        raise ValueError(
            f"on_fault must be 'raise', 'recover' or 'report', got {on_fault!r}"
        )
    if (verify or on_fault in ("recover", "report")) and edges is None:
        raise ValueError(
            "on_fault='recover'/'report' and verify=True need the original "
            "edge list — pass edges even when a prebuilt schedule is given"
        )
    if faults is not None and not faults.active:
        faults = None  # all sites off: share the clean compiled pipeline
    if schedule is None:
        if edges is None:
            raise ValueError("need either edges or a prebuilt schedule")
        schedule = build_window_schedule(
            edges, window, tile_size, dispersed, reorder=reorder
        )
    if interpret is None:
        interpret = _auto_interpret()
    spec = resolve_spec(spec)
    fn = _build_pipeline(
        schedule.num_windows,
        schedule.num_rows,
        schedule.tiles_per_window,
        schedule.tile_size,
        schedule.window,
        schedule.num_boundary_padded,
        schedule.num_edges,
        schedule.num_vertices,
        vector_rounds,
        bool(interpret),
        backend,
        conflict_method,
        faults,
        spec,
    )
    perm = schedule.perm
    if perm is None:
        perm = jnp.arange(schedule.num_vertices, dtype=jnp.int32)
    mask, state, conflicts, counters = fn(
        jnp.asarray(schedule.u_tiles),
        jnp.asarray(schedule.v_tiles),
        jnp.asarray(schedule.stream_src),
        jnp.asarray(schedule.boundary_blk_u),
        jnp.asarray(schedule.boundary_blk_v),
        jnp.asarray(schedule.boundary_ulocal),
        jnp.asarray(schedule.boundary_vlocal),
        jnp.asarray(schedule.window_ids),
        jnp.asarray(perm),
    )
    result = MatchResult(match_mask=mask, state=state, counters=counters)

    report = None
    if on_fault == "recover":
        rmask, rstate, residual, recovered, corrupted = residual_replay(
            edges, result.match_mask, result.state,
            tile_size=schedule.tile_size, vector_rounds=vector_rounds,
            spec=spec,
        )
        res_i, cor_i = (
            int(x) for x in
            jax.device_get((residual, corrupted))  # host-sync: ok (fault recovery)
        )
        result = MatchResult(match_mask=rmask, state=rstate, counters=counters)
        report = RecoveryReport(
            recovery_attempts=1 if (res_i or cor_i) else 0,
            residual_edges=res_i,
            recovered_matches=int(jax.device_get(recovered)),  # host-sync: ok
            corrupted_cells=cor_i,
        )
    elif on_fault == "report" or verify:
        residual, corrupted = detect_residual(
            edges, result.match_mask, result.state
        )
        res_i, cor_i = (
            int(x) for x in
            jax.device_get((residual, corrupted))  # host-sync: ok (fault report)
        )
        report = RecoveryReport(
            residual_edges=res_i, corrupted_cells=cor_i
        )
    if verify:
        chk = check_matching(edges, result.match_mask)
        ok_v, ok_m = (bool(x) for x in jax.device_get(  # host-sync: ok (verify path)
            (chk["valid"], chk["maximal"])
        ))
        if on_fault == "recover" and not (ok_v and ok_m):
            raise RuntimeError(
                "verify=True after on_fault='recover': recovered matching "
                f"failed validation (valid={ok_v}, maximal={ok_m}) — this "
                "is a bug in the recovery ladder, please report it"
            )
        if on_fault == "raise" and not (
            ok_v and ok_m
            and report.residual_edges == 0 and report.corrupted_cells == 0
        ):
            raise RuntimeError(
                "verify=True: matching failed validation "
                f"(valid={ok_v}, maximal={ok_m}, "
                f"residual_edges={report.residual_edges}, "
                f"corrupted_cells={report.corrupted_cells}) — run "
                "on_fault='recover' to complete it or 'report' to inspect"
            )

    out = (result,)
    if with_conflicts:
        out = out + (conflicts,)
    if on_fault != "raise":
        out = out + (report,)
    return out if len(out) > 1 else result
