"""Jit'd wrappers around the skipper_match Pallas kernel.

``skipper_match_window`` — raw windowed matcher (edges already window-local).
``skipper_match``        — full-graph driver: host-side windowing (the
    locality phase of the paper's scheduler), per-window kernel launches, and
    a pure-jnp cross-window cleanup pass for boundary edges. Every edge is
    still decided exactly once.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.types import EdgeList
from repro.core.types import ACC, MCHD, STATE_DTYPE, Counters, MatchResult
from repro.kernels.skipper_match.kernel import build_window_matcher


def skipper_match_window(
    u: jax.Array,
    v: jax.Array,
    state0: jax.Array,
    tile_size: int = 256,
    vector_rounds: int = 3,
    fallback: bool = True,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Match a window-local edge stream. u/v: int32[M] (padded to tile
    multiple with -1), state0: int32[W]. Returns (state, matched, conflicts).
    """
    m = u.shape[0]
    pad = (-m) % tile_size
    if pad:
        u = jnp.concatenate([u, jnp.full((pad,), -1, jnp.int32)])
        v = jnp.concatenate([v, jnp.full((pad,), -1, jnp.int32)])
    num_tiles = u.shape[0] // tile_size
    window = state0.shape[0]
    call = build_window_matcher(
        num_tiles, tile_size, window, vector_rounds, fallback, interpret
    )
    state, matched, conflicts = call(u, v, state0)
    return state, matched[:m], conflicts[:m]


def skipper_match(
    edges: EdgeList,
    window: int = 2048,
    tile_size: int = 256,
    vector_rounds: int = 3,
    interpret: bool = True,
) -> MatchResult:
    """Full-graph matcher: kernel on intra-window edges, jnp pass on the rest.

    Host-side bucketing is the locality phase: vertex id space is cut into
    windows of ``window`` ids; intra-window edges run through the VMEM kernel
    (the common case for locality-ordered graphs), boundary edges go through
    the exact sequential cleanup. Single pass per edge overall.
    """
    n = edges.num_vertices
    e = edges.canonical()
    u_np = np.asarray(e.u)
    v_np = np.asarray(e.v)
    m = u_np.shape[0]
    valid = (u_np >= 0) & (u_np != v_np)
    wu = u_np // window
    wv = v_np // window
    intra = valid & (wu == wv)
    num_windows = (n + window - 1) // window

    state = np.full((num_windows * window,), int(ACC), np.int32)
    matched = np.zeros((m,), bool)
    conflicts = np.zeros((m,), np.int32)

    # Phase 1: per-window kernel launches (independent subproblems — on a real
    # deployment these are the per-core shards; here they run sequentially).
    for w in range(num_windows):
        sel = np.nonzero(intra & (wu == w))[0]
        if sel.size == 0:
            continue
        base = w * window
        lu = jnp.asarray(u_np[sel] - base, jnp.int32)
        lv = jnp.asarray(v_np[sel] - base, jnp.int32)
        st0 = jnp.asarray(state[base : base + window])
        st, mt, cf = skipper_match_window(
            lu, lv, st0, tile_size, vector_rounds, True, interpret
        )
        state[base : base + window] = np.asarray(st)
        matched[sel] = np.asarray(mt).astype(bool)
        conflicts[sel] = np.asarray(cf)

    # Phase 2: boundary edges — exact sequential greedy against global state.
    sel = np.nonzero(valid & ~intra)[0]
    if sel.size:
        st = jnp.asarray(state[:n])

        def fstep(stt, uv):
            uu, vv = uv
            take = (stt[uu] == ACC) & (stt[vv] == ACC)
            stt = stt.at[jnp.where(take, uu, n)].set(MCHD, mode="drop")
            stt = stt.at[jnp.where(take, vv, n)].set(MCHD, mode="drop")
            return stt, take

        st, takes = jax.lax.scan(
            fstep, st, (jnp.asarray(u_np[sel]), jnp.asarray(v_np[sel]))
        )
        state[:n] = np.asarray(st)
        matched[sel] = np.asarray(takes)

    counters = Counters(
        edge_reads=jnp.asarray(m, jnp.int32),
        state_loads=jnp.asarray(2 * m + 2 * int(conflicts.sum()), jnp.int32),
        state_stores=jnp.asarray(2 * int(matched.sum()), jnp.int32),
        rounds=jnp.asarray(1, jnp.int32),
    )
    return MatchResult(
        match_mask=jnp.asarray(matched),
        state=jnp.asarray(state[:n], STATE_DTYPE),
        counters=counters,
    )
