from repro.kernels.skipper_match.ops import skipper_match_window, skipper_match
from repro.kernels.skipper_match.ref import ref_match_window

__all__ = ["skipper_match_window", "skipper_match", "ref_match_window"]
