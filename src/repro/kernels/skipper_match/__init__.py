from repro.kernels.skipper_match.ops import (
    skipper_match_window,
    skipper_match,
    pipeline_trace_count,
)
from repro.kernels.skipper_match.ref import ref_match_window, make_ref_pipeline

__all__ = [
    "skipper_match_window",
    "skipper_match",
    "pipeline_trace_count",
    "ref_match_window",
    "make_ref_pipeline",
]
