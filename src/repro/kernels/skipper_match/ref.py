"""Pure-jnp oracle for the skipper_match kernel.

Implements *bit-identical* semantics to kernel.skipper_window_kernel (same
tile order, same vector rounds, same first-claim rule, same fallback), so
tests can assert exact equality of the matched mask and final state, plus the
algorithm-level properties (validity, maximality) against core.sgmm.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

ACC = 0
MCHD = 2


@partial(jax.jit, static_argnames=("vector_rounds", "fallback"))
def ref_match_window(
    u_tiles: jax.Array,   # int32[num_tiles, T]
    v_tiles: jax.Array,   # int32[num_tiles, T]
    state0: jax.Array,    # int32[W]
    vector_rounds: int = 3,
    fallback: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (state, matched int32[num_tiles*T], conflicts int32[...])."""
    w = state0.shape[0]
    t = u_tiles.shape[1]

    def tile_step(state, uv):
        u, v = uv
        valid = (u >= 0) & (u != v)
        share = (
            (u[:, None] == u[None, :])
            | (u[:, None] == v[None, :])
            | (v[:, None] == u[None, :])
            | (v[:, None] == v[None, :])
        )
        lower = jnp.tril(jnp.ones((t, t), jnp.bool_), k=-1)
        conflict = share & lower & valid[None, :] & valid[:, None]

        matched = jnp.zeros((t,), jnp.bool_)
        conflicts = jnp.zeros((t,), jnp.int32)
        for _ in range(vector_rounds):
            su = state[jnp.where(valid, u, 0)]
            sv = state[jnp.where(valid, v, 0)]
            free = valid & (~matched) & (su == ACC) & (sv == ACC)
            blocked = jnp.any(conflict & free[None, :], axis=1) & free
            commit = free & ~blocked
            state = state.at[jnp.where(commit, u, w)].set(MCHD, mode="drop")
            state = state.at[jnp.where(commit, v, w)].set(MCHD, mode="drop")
            matched = matched | commit
            conflicts = conflicts + blocked.astype(jnp.int32)

        if fallback:
            su = state[jnp.where(valid, u, 0)]
            sv = state[jnp.where(valid, v, 0)]
            remaining = valid & (~matched) & (su == ACC) & (sv == ACC)

            def body(i, carry):
                state, matched = carry
                rem_i = remaining[i]
                ui = u[i]
                vi = v[i]
                s_u = state[jnp.where(rem_i, ui, 0)]
                s_v = state[jnp.where(rem_i, vi, 0)]
                take = rem_i & (s_u == ACC) & (s_v == ACC)
                state = jnp.where(
                    take, state.at[ui].set(MCHD).at[vi].set(MCHD), state
                )
                matched = matched.at[i].set(matched[i] | take)
                return state, matched

            state, matched = jax.lax.fori_loop(0, t, body, (state, matched))

        return state, (matched.astype(jnp.int32), conflicts)

    state, (matched, conflicts) = jax.lax.scan(tile_step, state0, (u_tiles, v_tiles))
    return state, matched.reshape(-1), conflicts.reshape(-1)
