"""Pure-jnp oracle for the skipper_match kernels.

Implements *bit-identical* semantics to ``kernel.skipper_window_kernel`` /
``kernel.skipper_pipeline_kernel`` (same tile order, same vector rounds, same
first-claim rule, same fallback), so tests can assert exact equality of the
matched mask and final state, plus the algorithm-level properties (validity,
maximality) against core.sgmm.

Both the kernel and this oracle consume ``core/engine.py`` for the conflict
matrix and commit rule; only the gather/scatter differs (MXU one-hot matmuls
there, ``.at`` indexing here), which is exactly the part exact-equality tests
pin down.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.statespec import StateSpec, resolve as resolve_spec


@partial(jax.jit, static_argnames=("vector_rounds", "fallback", "spec"))
def ref_match_window(
    u_tiles: jax.Array,   # int32[num_tiles, T]
    v_tiles: jax.Array,   # int32[num_tiles, T]
    state0: jax.Array,    # spec.vmem[W]
    vector_rounds: int = 1,
    fallback: bool = True,
    spec: StateSpec | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (state, matched spec.counter[num_tiles*T], conflicts[...]).
    ``state0``'s dtype is the caller's; matched/conflicts follow the spec
    like ``build_window_matcher``'s outputs do."""
    spec = resolve_spec(spec)
    w = state0.shape[0]
    cdt = spec.counter_dtype

    def tile_step(state, uv):
        u, v = uv
        state, matched, conflicts, _fb = engine.tile_pass(
            state, u, v, n=w, vector_rounds=vector_rounds, fallback=fallback,
            spec=spec,
        )
        return state, (matched.astype(cdt), conflicts)

    state, (matched, conflicts) = jax.lax.scan(tile_step, state0, (u_tiles, v_tiles))
    return state, matched.reshape(-1), conflicts.reshape(-1)


def make_ref_pipeline(window: int, vector_rounds: int = 1,
                      spec: StateSpec | None = None):
    """Build the jnp twin of ``build_pipeline_matcher`` for a fixed window
    size: every window starts from all-ACC state and runs its tiles in order.

    ONE flat sequential scan over the (row, tile) steps, tile innermost —
    exactly the Pallas grid's iteration order, so decisions are
    bit-identical; the state carry is reset to all-ACC at each row's first
    tile (the revolving VMEM block's re-initialization). Windows are
    independent, so a vmap over rows would also be correct — but under vmap
    the fallback ``while_loop`` pays the batch-max iteration count on every
    row and ``lax.cond`` can't skip, which measured ~2-4x slower on CPU than
    this serial form (the XLA twin exists to be timed on CPU; the Pallas
    path owns the parallel hardware). A scan-of-scans over (rows, tiles)
    is equivalent but measured ~20% slower (per-row output stacking).

    State and counter widths come from the spec (``core/statespec.py``):
    the default carries uint8 end-to-end — the paper's 1 B/vertex encoding —
    and the engine compares against plain ints so any width computes the
    same values (bit-equal across specs, test-pinned). The twin and the
    Pallas kernel share the spec, so their output *dtypes* match too.

    The returned callable maps (u_tiles, v_tiles)
    int32[num_rows, tiles_per_window, T] (window-local ids) to
    (state spec.vmem[num_rows, window], matched spec.counter[num_rows,
    tpw*T], conflicts spec.counter[...]).
    """
    spec = resolve_spec(spec)
    cdt = spec.counter_dtype

    def run(u3, v3):
        num_rows, tpw, t = u3.shape
        uf = u3.reshape(num_rows * tpw, t)
        vf = v3.reshape(num_rows * tpw, t)
        steps = jnp.arange(num_rows * tpw, dtype=jnp.int32)
        fresh = steps % tpw == 0  # first tile of each row: reset the block

        def tile_step(state, uvf):
            u, v, fr = uvf
            state = jnp.where(fr, jnp.zeros_like(state), state)
            state, matched, conflicts, _fb = engine.tile_pass(
                state, u, v, n=window, vector_rounds=vector_rounds, spec=spec
            )
            return state, (state, matched.astype(cdt), conflicts)

        state0 = jnp.zeros((window,), spec.vmem_dtype)
        _, (states, matched, conflicts) = jax.lax.scan(
            tile_step, state0, (uf, vf, fresh)
        )
        return (
            states[tpw - 1 :: tpw],          # each row's final state
            matched.reshape(num_rows, tpw * t),
            conflicts.reshape(num_rows, tpw * t),
        )

    return run
