"""Pure-jnp oracle for the skipper_match kernels.

Implements *bit-identical* semantics to ``kernel.skipper_window_kernel`` /
``kernel.skipper_pipeline_kernel`` (same tile order, same vector rounds, same
first-claim rule, same fallback), so tests can assert exact equality of the
matched mask and final state, plus the algorithm-level properties (validity,
maximality) against core.sgmm.

Both the kernel and this oracle consume ``core/engine.py`` for the conflict
matrix and commit rule; only the gather/scatter differs (MXU one-hot matmuls
there, ``.at`` indexing here), which is exactly the part exact-equality tests
pin down.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import engine


@partial(jax.jit, static_argnames=("vector_rounds", "fallback"))
def ref_match_window(
    u_tiles: jax.Array,   # int32[num_tiles, T]
    v_tiles: jax.Array,   # int32[num_tiles, T]
    state0: jax.Array,    # int32[W]
    vector_rounds: int = 3,
    fallback: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (state, matched int32[num_tiles*T], conflicts int32[...])."""
    w = state0.shape[0]

    def tile_step(state, uv):
        u, v = uv
        state, matched, conflicts, _fb = engine.tile_pass(
            state, u, v, n=w, vector_rounds=vector_rounds, fallback=fallback
        )
        return state, (matched.astype(jnp.int32), conflicts)

    state, (matched, conflicts) = jax.lax.scan(tile_step, state0, (u_tiles, v_tiles))
    return state, matched.reshape(-1), conflicts.reshape(-1)


def make_ref_pipeline(window: int, vector_rounds: int = 3):
    """Build the jnp twin of ``build_pipeline_matcher`` for a fixed window
    size: every window starts from all-ACC state and runs its tiles in order.
    Windows are independent, so they vectorize with vmap (the XLA analogue of
    the revolving VMEM block). The returned callable maps
    (u_tiles, v_tiles) int32[num_windows, tiles_per_window, T] (local ids) to
    (state int32[nw, window], matched int32[nw, tpw*T], conflicts int32[...]).
    """

    def one_window(u_t, v_t):  # [tiles_per_window, T] local ids
        state0 = jnp.zeros((window,), jnp.int32)

        def tile_step(state, uv):
            u, v = uv
            state, matched, conflicts, _fb = engine.tile_pass(
                state, u, v, n=window, vector_rounds=vector_rounds
            )
            return state, (matched.astype(jnp.int32), conflicts)

        state, (matched, conflicts) = jax.lax.scan(tile_step, state0, (u_t, v_t))
        return state, matched.reshape(-1), conflicts.reshape(-1)

    return jax.vmap(one_window)
