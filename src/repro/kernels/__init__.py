"""Pallas TPU kernels for the perf-critical compute layers.

skipper_match/    — the paper's hot loop: windowed single-pass greedy matching
flash_attention/  — causal/GQA/sliding-window attention for the LM substrate

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle). Validated with interpret=True on CPU;
BlockSpecs are written for TPU VMEM tiling (see docstrings).
"""
