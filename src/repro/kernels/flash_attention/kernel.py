"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Targets the MXU: grid = (batch, q_heads, q_blocks); each step owns a
(block_q x head_dim) query tile in VMEM, loops over key/value chunks with the
online-softmax recurrence, accumulating in f32. KV for the (grouped) head is
BlockSpec-mapped into VMEM once per (batch, head) and reused across q blocks.

Causal + sliding-window masks are applied with 2-D iota position grids; the
kv-chunk loop upper bound is trimmed to the causal frontier so past-diagonal
chunks are never touched (the flash-attention work-skipping trick, which is
what makes the SWA variant O(S * window)).

Block shapes: block_q x head_dim and block_k x head_dim tiles with
head_dim in {64, 80, 128} — multiples of 8x128 VREG packing for f32; bf16
inputs are upcast at the MXU boundary (preferred_element_type=f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def flash_attention_kernel(
    q_ref,   # [1, 1, block_q, d]
    k_ref,   # [1, 1, S, d]
    v_ref,   # [1, 1, S, d]
    o_ref,   # [1, 1, block_q, d]
    *,
    block_k: int,
    sm_scale: float,
    causal: bool,
    window: int,   # 0 = disabled; else only attend to last `window` positions
):
    block_q = q_ref.shape[2]
    d = q_ref.shape[3]
    s = k_ref.shape[2]
    qi = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    num_kv = s // block_k
    if causal:
        # last kv chunk that intersects the causal frontier of this q block
        hi = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k, num_kv)
    else:
        hi = num_kv

    def body(j, carry):
        m_i, l_i, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [block_q, block_k]
        kv_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kv_pos <= q_pos
        if window > 0:
            mask &= kv_pos > q_pos - window
        scores = jnp.where(mask, scores, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(scores, axis=1))
        p = jnp.exp(scores - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    if window > 0 and causal:
        lo = jnp.maximum(qi * block_q - window + 1, 0) // block_k
    else:
        lo = 0

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, d), jnp.float32)
    m_i, l_i, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))

    l_safe = jnp.where(l_i > 0, l_i, 1.0)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(o_ref.dtype)


def build_flash_attention(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_len: int,
    head_dim: int,
    block_q: int = 128,
    block_k: int = 128,
    sm_scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
    out_dtype=jnp.bfloat16,
):
    assert seq_len % block_q == 0 and seq_len % block_k == 0
    assert num_q_heads % num_kv_heads == 0
    group = num_q_heads // num_kv_heads
    if sm_scale is None:
        sm_scale = head_dim ** -0.5
    kernel = functools.partial(
        flash_attention_kernel,
        block_k=block_k,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
    )
    grid = (batch, num_q_heads, seq_len // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, head_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, seq_len, head_dim), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, seq_len, head_dim), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, head_dim), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (batch, num_q_heads, seq_len, head_dim), out_dtype
        ),
        interpret=interpret,
    )
