from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import ref_attention

__all__ = ["flash_attention", "ref_attention"]
