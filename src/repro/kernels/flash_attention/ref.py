"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("causal", "window", "sm_scale"))
def ref_attention(
    q: jax.Array,  # [B, Hq, S, D]
    k: jax.Array,  # [B, Hkv, S, D]
    v: jax.Array,  # [B, Hkv, S, D]
    causal: bool = True,
    window: int = 0,
    sm_scale: float | None = None,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * sm_scale
    q_pos = jnp.arange(s)[:, None]
    kv_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), jnp.bool_)
    if causal:
        mask &= kv_pos <= q_pos
    if window > 0:
        mask &= kv_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out
