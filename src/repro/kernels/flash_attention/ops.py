"""Jit'd wrapper for the flash attention Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import build_flash_attention


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Drop-in attention: q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D]."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    call = build_flash_attention(
        b, hq, hkv, s, d,
        block_q=bq, block_k=bk, sm_scale=sm_scale,
        causal=causal, window=window, interpret=interpret,
        out_dtype=q.dtype,
    )
    return call(q, k, v)
