"""One-shot host precompute of the device-resident window schedule.

The paper's locality phase cuts the vertex-id space into windows of ``window``
ids and buckets canonical edges by window so the hot loop only ever touches a
VMEM-sized slice of the state array. The old driver re-derived this per window
on the host, with a numpy round-trip between Pallas launches; this module
computes the *whole* schedule once, with static shapes, so the kernel driver
traces a single ``pallas_call`` over a 2-D ``(window, tile)`` grid and never
returns to the host mid-graph.

Layout (see DESIGN.md "Window-schedule layout"):

    u_tiles / v_tiles : int32[num_windows, tiles_per_window * tile_size]
        window-LOCAL endpoint ids (global id minus window * window_size),
        -1 padding. Row w, flattened slot t * tile_size + l is tile t, lane l
        of window w.
    edge_index        : same shape; original stream index of the edge in that
        slot (-1 for padding). This is the slot -> stream half of the
        round-trip mapping; ``stream_to_slot`` computes the inverse.
    boundary_u/v/index: int32[num_boundary_padded] cross-window edges in
        stream order (GLOBAL ids), padded to a tile multiple; resolved by the
        in-device epilogue against the full state.

The dispersed deal (paper §IV-C) is applied *within* each window: lane l of
the window's tile stream walks its own contiguous run of that window's edges
(locality preserved per lane) while the lanes of any one tile sit far apart
in the window's stream (dispersed), keeping intra-tile endpoint sharing — the
JIT-conflict source — Θ(λ²)-rare.

``tiles_per_window`` is the max over windows (static shapes are the price of
a single compilation unit); skewed graphs pay padding for it — see DESIGN.md
§2 A7 for the accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.graphs.types import EdgeList


@dataclasses.dataclass(frozen=True)
class WindowSchedule:
    """Static-shape device schedule for one graph. All arrays are host numpy;
    the driver moves them to device once, at trace time."""

    window: int           # vertex ids per window
    tile_size: int
    num_windows: int
    tiles_per_window: int
    num_vertices: int
    num_edges: int        # original stream length (mask/conflicts length)
    u_tiles: np.ndarray   # int32[num_windows, tiles_per_window * tile_size], local ids
    v_tiles: np.ndarray
    edge_index: np.ndarray  # int32, same shape, stream index or -1
    boundary_u: np.ndarray  # int32[num_boundary_padded], global ids
    boundary_v: np.ndarray
    boundary_index: np.ndarray

    @property
    def num_boundary_padded(self) -> int:
        return int(self.boundary_u.shape[0])

    def slot_to_stream(self) -> np.ndarray:
        """int32[num_windows, tiles_per_window, tile_size] — stream index of
        each schedule slot (-1 = padding)."""
        return self.edge_index.reshape(
            self.num_windows, self.tiles_per_window, self.tile_size
        )

    def stream_to_slot(self) -> np.ndarray:
        """int32[num_edges, 3] — (window, tile, lane) of each stream position,
        or (-1, -1, -1) for edges not in the windowed schedule (boundary /
        invalid edges)."""
        out = np.full((self.num_edges, 3), -1, np.int32)
        s2s = self.slot_to_stream()
        w, t, l = np.nonzero(s2s >= 0)
        out[s2s[w, t, l]] = np.stack([w, t, l], axis=1).astype(np.int32)
        return out


def _dispersed_within(idx: np.ndarray, tiles: int, tile_size: int) -> np.ndarray:
    """Deal a window's padded stream [tiles * tile_size] so tile t, lane l
    holds stream slot l * tiles + t: each lane walks a contiguous run, lanes
    of one tile are ``tiles`` apart."""
    return idx.reshape(tile_size, tiles).T.reshape(-1)


def build_window_schedule(
    edges: EdgeList,
    window: int = 2048,
    tile_size: int = 256,
    dispersed: bool = True,
) -> WindowSchedule:
    """Bucket canonical edges by vertex window and pack the dense schedule.

    Pure host/numpy, one pass over the edge list; every output shape depends
    only on (graph, window, tile_size) so the device driver traces once.
    """
    n = edges.num_vertices
    e = edges.canonical()
    u = np.asarray(e.u)
    v = np.asarray(e.v)
    m = int(u.shape[0])

    valid = (u >= 0) & (u != v)
    wu = np.where(valid, u // window, 0)
    wv = np.where(valid, v // window, 0)
    intra = valid & (wu == wv)
    boundary = valid & ~intra
    num_windows = max(1, -(-n // window))

    counts = np.bincount(wu[intra], minlength=num_windows)
    tiles_per_window = max(1, int(-(-counts.max() // tile_size))) if m else 1
    slots = tiles_per_window * tile_size

    u_tiles = np.full((num_windows, slots), -1, np.int32)
    v_tiles = np.full((num_windows, slots), -1, np.int32)
    edge_index = np.full((num_windows, slots), -1, np.int32)

    # stable bucket: edges of window w in stream order
    order = np.nonzero(intra)[0]
    win_of = wu[order]
    sort = np.argsort(win_of, kind="stable")
    order = order[sort]
    starts = np.concatenate([[0], np.cumsum(counts)])
    for w in range(num_windows):
        sel = order[starts[w] : starts[w + 1]]
        if sel.size == 0:
            continue
        pad = np.full((slots,), -1, np.int64)
        pad[: sel.size] = sel
        if dispersed:
            pad = _dispersed_within(pad, tiles_per_window, tile_size)
        present = pad >= 0
        src = np.where(present, pad, 0)
        base = w * window
        u_tiles[w] = np.where(present, u[src] - base, -1).astype(np.int32)
        v_tiles[w] = np.where(present, v[src] - base, -1).astype(np.int32)
        edge_index[w] = np.where(present, pad, -1).astype(np.int32)

    bsel = np.nonzero(boundary)[0]
    nb = int(bsel.size)
    nb_pad = -(-nb // tile_size) * tile_size if nb else 0
    boundary_u = np.full((nb_pad,), -1, np.int32)
    boundary_v = np.full((nb_pad,), -1, np.int32)
    boundary_index = np.full((nb_pad,), -1, np.int32)
    boundary_u[:nb] = u[bsel]
    boundary_v[:nb] = v[bsel]
    boundary_index[:nb] = bsel.astype(np.int32)

    return WindowSchedule(
        window=window,
        tile_size=tile_size,
        num_windows=num_windows,
        tiles_per_window=tiles_per_window,
        num_vertices=n,
        num_edges=m,
        u_tiles=u_tiles,
        v_tiles=v_tiles,
        edge_index=edge_index,
        boundary_u=boundary_u,
        boundary_v=boundary_v,
        boundary_index=boundary_index,
    )
