"""One-shot host precompute of the device-resident window schedule.

The paper's locality phase cuts the vertex-id space into windows of ``window``
ids and buckets canonical edges by window so the hot loop only ever touches a
VMEM-sized slice of the state array. The old driver re-derived this per window
on the host, with a numpy round-trip between Pallas launches; this module
computes the *whole* schedule once, with static shapes, so the kernel driver
traces a single ``pallas_call`` over a 2-D ``(row, tile)`` grid and never
returns to the host mid-graph.

Two refinements over the naive bucketing (DESIGN.md §2 A7, §8):

* **Locality reordering** (``reorder=``): vertices are renumbered by a
  ``graphs/reorder.py`` policy before bucketing, so permuted / power-law
  inputs reach grid-like intra-window fractions. The schedule carries the
  permutation (``perm``/``inv``); the driver maps results back to original
  ids, so callers never see renumbered vertices.
* **Two-tier schedule** (``coalesce_sparse=``): ``tiles_per_window`` is a
  static max, so skewed graphs used to pay padding for every window. Now
  only *dense* windows (tile occupancy >= ``sparse_occupancy`` of the
  densest window's row) get rows in the 2-D grid; sparse windows are
  coalesced into the global stream next to the cross-window edges and
  resolved by the boundary epilogue against the full state — batched tiles,
  zero per-window padding. ``window_ids`` maps schedule rows back to window
  ids (rows are compacted).

Layout (see DESIGN.md "Window-schedule layout"):

    u_tiles / v_tiles : int32[num_rows, tiles_per_window * tile_size]
        window-LOCAL endpoint ids (renumbered-global id minus
        window_ids[row] * window), -1 padding. Row r, flattened slot
        t * tile_size + l is tile t, lane l of window window_ids[r].
    edge_index        : same shape; original stream index of the edge in that
        slot (-1 for padding). This is the slot -> stream half of the
        round-trip mapping; ``stream_to_slot`` computes the inverse.
    boundary_u/v/index: int32[num_boundary_padded] global-tier edges
        (renumbered GLOBAL ids): cross-window edges plus the edges of
        coalesced sparse windows, grouped by **block pair** — the
        (u-window, v-window) pair of each edge — in lexicographic pair
        order, stream-stable within each pair, with every pair group padded
        to a tile multiple so each tile touches exactly one pair. Resolved
        by the in-device block-pair epilogue (DESIGN.md §10), which streams
        only the pair's two window-sized state blocks per tile.
    boundary_ulocal/vlocal: int32[num_boundary_padded] the same edges in the
        epilogue's OFFSET-LOCAL encoding: u minus its block base (in
        [0, window)); v minus its block base, **plus window when the pair is
        cross-block** (in [0, 2*window)) — so the concatenated two-block
        state of a pair tile behaves as one 2*window-vertex id space and
        same-block pairs degenerate to the first block alone.
    boundary_blk_u/blk_v: int32[num_boundary_tiles] per-TILE state-block ids
        of the pair (the scalar-prefetch operands of the Pallas epilogue;
        num_boundary_tiles = num_boundary_padded // tile_size).

The dispersed deal (paper §IV-C) is applied *within* each window: lane l of
the window's tile stream walks its own contiguous run of that window's edges
(locality preserved per lane) while the lanes of any one tile sit far apart
in the window's stream (dispersed), keeping intra-tile endpoint sharing — the
JIT-conflict source — Θ(λ²)-rare.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graphs.types import EdgeList
from repro.graphs.reorder import Reordering, reorder_vertices


@dataclasses.dataclass(frozen=True)
class WindowSchedule:
    """Static-shape device schedule for one graph. All arrays are host numpy;
    the driver moves them to device once, at trace time.

    Consumed by the single-device pipeline (``kernels/skipper_match/ops``)
    and, via ``graphs/partition.partition_schedule``, by the
    locality-sharded distributed matcher — windows are disjoint vertex-id
    ranges, so whole rows can be dealt to devices and resolved without
    communication (DESIGN.md §8)."""

    window: int           # vertex ids per window
    tile_size: int
    num_windows: int      # windows covering the id space (state rows)
    tiles_per_window: int
    num_vertices: int
    num_edges: int        # original stream length (mask/conflicts length)
    u_tiles: np.ndarray   # int32[num_rows, tiles_per_window * tile_size], local ids
    v_tiles: np.ndarray
    edge_index: np.ndarray  # int32, same shape, stream index or -1
    boundary_u: np.ndarray  # int32[num_boundary_padded], global ids,
    boundary_v: np.ndarray  #   block-pair grouped order (see module doc)
    boundary_index: np.ndarray
    # block-pair epilogue operands (same grouped order; see module doc)
    boundary_ulocal: np.ndarray = None  # int32[num_boundary_padded]
    boundary_vlocal: np.ndarray = None  # int32[num_boundary_padded]
    boundary_blk_u: np.ndarray = None   # int32[num_boundary_tiles]
    boundary_blk_v: np.ndarray = None   # int32[num_boundary_tiles]
    # two-tier bookkeeping: schedule row r holds window window_ids[r]
    window_ids: np.ndarray = None  # int32[num_rows], default arange
    # locality reordering (None = identity / not reordered)
    reorder: str = "none"
    perm: Optional[np.ndarray] = None   # int32[n]: original id -> renumbered id
    inv: Optional[np.ndarray] = None    # int32[n]: renumbered id -> original id
    # measured locality/packing stats (set by build_window_schedule)
    num_valid: int = 0     # valid edges in the stream
    num_intra: int = 0     # valid edges with both endpoints in one window
    num_windowed: int = 0  # edges placed in the dense (2-D grid) tier
    # stream_src[k] = flat decision-slot index of stream position k in
    # [windowed slots ++ global-tier slots ++ one always-zero pad slot] —
    # lets the driver GATHER decisions back to stream order (a device
    # scatter of |E| indices costs ~100x more than the gather on CPU XLA).
    stream_src: Optional[np.ndarray] = None  # int32[num_edges]

    def __post_init__(self):
        if self.window_ids is None:
            object.__setattr__(
                self, "window_ids", np.arange(self.num_rows, dtype=np.int32)
            )

    @property
    def num_rows(self) -> int:
        return int(self.u_tiles.shape[0])

    @property
    def num_boundary_padded(self) -> int:
        return int(self.boundary_u.shape[0])

    @property
    def num_boundary_tiles(self) -> int:
        return self.num_boundary_padded // self.tile_size

    @property
    def num_boundary_pairs(self) -> int:
        """Distinct (u-window, v-window) block pairs in the global tier."""
        if self.boundary_blk_u is None or not self.boundary_blk_u.size:
            return 0
        key = (
            self.boundary_blk_u.astype(np.int64) * self.num_windows
            + self.boundary_blk_v
        )
        return int(np.unique(key).size)

    @property
    def intra_fraction(self) -> float:
        """Fraction of valid edges intra-window after reordering — the
        locality number the benches report."""
        return self.num_intra / max(1, self.num_valid)

    @property
    def windowed_fraction(self) -> float:
        """Fraction of valid edges resolved in the dense VMEM-resident tier
        (<= intra_fraction: sparse windows are coalesced into the global
        tier)."""
        return self.num_windowed / max(1, self.num_valid)

    @property
    def padding_waste(self) -> float:
        """Fraction of scheduled slots (both tiers) that are padding."""
        total = self.num_rows * self.tiles_per_window * self.tile_size
        total += self.num_boundary_padded
        used = self.num_windowed + int((self.boundary_index >= 0).sum())
        return (total - used) / max(1, total)

    def vmem_state_bytes(self, spec=None) -> int:
        """Bytes of the revolving per-step VMEM state block under ``spec``
        (a ``core/statespec.StateSpec``; default the package spec): the
        window tier carries one ``window``-cell block per grid step, the
        boundary epilogue a two-window pair — this returns the LARGER of
        the two, the figure the roofline and bench reports quote."""
        from repro.core.statespec import resolve as resolve_spec

        spec = resolve_spec(spec)
        blocks = 2 if self.num_boundary_padded > 0 else 1
        return blocks * self.window * spec.vmem_bytes

    def wire_state_bytes(self, spec=None, num_devices: int = 1) -> int:
        """Bytes of the distributed PHASE A state-assembly payload under
        ``spec``: every device contributes its ``num_rows x window`` row
        scatter to one O(V) combine (``distributed.locality_sharded_fn``),
        at the spec's wire width."""
        from repro.core.statespec import resolve as resolve_spec

        spec = resolve_spec(spec)
        return num_devices * self.num_rows * self.window * spec.wire_bytes

    def slot_to_stream(self) -> np.ndarray:
        """int32[num_rows, tiles_per_window, tile_size] — stream index of
        each schedule slot (-1 = padding)."""
        return self.edge_index.reshape(
            self.num_rows, self.tiles_per_window, self.tile_size
        )

    def stream_to_slot(self) -> np.ndarray:
        """int32[num_edges, 3] — (row, tile, lane) of each stream position,
        or (-1, -1, -1) for edges not in the windowed tier (global-tier /
        invalid edges)."""
        out = np.full((self.num_edges, 3), -1, np.int32)
        s2s = self.slot_to_stream()
        w, t, l = np.nonzero(s2s >= 0)
        out[s2s[w, t, l]] = np.stack([w, t, l], axis=1).astype(np.int32)
        return out


def _dispersed_within(idx: np.ndarray, tiles: int, tile_size: int) -> np.ndarray:
    """Deal a window's padded stream [tiles * tile_size] so tile t, lane l
    holds stream slot l * tiles + t: each lane walks a contiguous run, lanes
    of one tile are ``tiles`` apart."""
    return idx.reshape(tile_size, tiles).T.reshape(-1)


def build_window_schedule(
    edges: EdgeList,
    window: int = 2048,
    tile_size: int = 256,
    dispersed: bool = True,
    reorder: str = "none",
    reordering: Optional[Reordering] = None,
    coalesce_sparse: bool = True,
    sparse_occupancy: float = 0.25,
) -> WindowSchedule:
    """Bucket canonical edges by vertex window and pack the two-tier schedule.

    Pure host/numpy, one pass over the edge list (plus the optional
    reordering pass); every output shape depends only on (graph, window,
    tile_size, reorder policy) so the device driver traces once.

    ``reorder`` names a ``graphs/reorder.py`` policy (or pass a precomputed
    ``reordering``); ``coalesce_sparse`` routes windows whose row occupancy
    would be below ``sparse_occupancy`` (relative to the densest window's
    padded row) into the global tier instead of padding them.
    """
    n = edges.num_vertices
    e = edges.canonical()
    u = np.asarray(e.u).astype(np.int64)
    v = np.asarray(e.v).astype(np.int64)
    m = int(u.shape[0])
    valid = (u >= 0) & (u != v)

    if reordering is None and reorder != "none":
        reordering = reorder_vertices(edges, reorder, window=window)
    perm = inv = None
    if reordering is not None and reordering.policy != "none":
        perm = reordering.perm
        inv = reordering.inv
        reorder = reordering.policy
        u = np.where(valid, perm[np.where(valid, u, 0)], u)
        v = np.where(valid, perm[np.where(valid, v, 0)], v)
    else:
        reorder = "none"

    wu = np.where(valid, u // window, 0)
    wv = np.where(valid, v // window, 0)
    intra = valid & (wu == wv)
    num_windows = max(1, -(-n // window))

    counts = np.bincount(wu[intra], minlength=num_windows)
    max_count = int(counts.max()) if m else 0

    # ---- two-tier split: dense windows get grid rows, sparse ones coalesce
    if coalesce_sparse and num_windows > 1 and max_count > 0:
        tiles_max = -(-max_count // tile_size)
        occupancy = counts / (tiles_max * tile_size)
        dense = occupancy >= sparse_occupancy
        dense[np.argmax(counts)] = True     # densest window is always a row
        dense &= counts > 0
        if not dense.any():
            dense = counts > 0
    else:
        dense = counts > 0 if max_count > 0 else np.zeros(num_windows, bool)
        if not dense.any():
            dense = np.ones(num_windows, bool)
            dense[1:] = False
    dense_ids = np.nonzero(dense)[0]
    if dense_ids.size == 0:
        dense_ids = np.array([0], np.int64)
    num_rows = int(dense_ids.size)
    dense_max = int(counts[dense_ids].max()) if m else 0
    tiles_per_window = max(1, -(-dense_max // tile_size)) if m else 1
    slots = tiles_per_window * tile_size

    coalesced = intra & ~dense[wu]          # sparse windows' edges
    windowed = intra & dense[wu]
    global_tier = valid & ~windowed         # boundary + coalesced, stream order

    u_tiles = np.full((num_rows, slots), -1, np.int32)
    v_tiles = np.full((num_rows, slots), -1, np.int32)
    edge_index = np.full((num_rows, slots), -1, np.int32)

    # stable bucket: windowed edges of window w in stream order
    order = np.nonzero(windowed)[0]
    win_of = wu[order]
    sort = np.argsort(win_of, kind="stable")
    order = order[sort]
    wcounts = counts * dense                # windowed edges per window
    starts = np.concatenate([[0], np.cumsum(wcounts[dense_ids])])
    for r, w in enumerate(dense_ids):
        sel = order[starts[r] : starts[r + 1]]
        if sel.size == 0:
            continue
        pad = np.full((slots,), -1, np.int64)
        pad[: sel.size] = sel
        if dispersed:
            pad = _dispersed_within(pad, tiles_per_window, tile_size)
        present = pad >= 0
        src = np.where(present, pad, 0)
        base = w * window
        u_tiles[r] = np.where(present, u[src] - base, -1).astype(np.int32)
        v_tiles[r] = np.where(present, v[src] - base, -1).astype(np.int32)
        edge_index[r] = np.where(present, pad, -1).astype(np.int32)

    # ---- global tier: block-pair grouping (DESIGN.md §10) ----------------
    # Group the global-tier stream by the (u-window, v-window) pair of each
    # edge — canonical u <= v gives blk_u <= blk_v — in lexicographic pair
    # order, STABLE within a pair (the stream stays a genuine single pass:
    # each edge is decided once, in a deterministic schedule order). Each
    # pair group is padded to a tile multiple so every epilogue tile touches
    # exactly one pair and the kernel streams just two window-sized state
    # blocks per grid step instead of the full flattened state.
    bsel = np.nonzero(global_tier)[0]
    nb = int(bsel.size)
    if nb:
        ub, vb = u[bsel], v[bsel]
        pu, pv = ub // window, vb // window
        pair_key = pu * num_windows + pv
        order_b = np.argsort(pair_key, kind="stable")
        bsel, ub, vb = bsel[order_b], ub[order_b], vb[order_b]
        pu, pv = pu[order_b], pv[order_b]
        # pair run boundaries -> per-pair tile padding
        starts_b = np.concatenate(
            [[0], np.nonzero(np.diff(pair_key[order_b]))[0] + 1, [nb]]
        )
        sizes = np.diff(starts_b)
        padded_sizes = -(-sizes // tile_size) * tile_size
        nb_pad = int(padded_sizes.sum())
        # grouped slot of in-pair position k of pair p: pad_start[p] + k
        pad_starts = np.concatenate([[0], np.cumsum(padded_sizes)])[:-1]
        slot_of = np.repeat(pad_starts - starts_b[:-1], sizes) + np.arange(nb)
        boundary_u = np.full((nb_pad,), -1, np.int32)
        boundary_v = np.full((nb_pad,), -1, np.int32)
        boundary_index = np.full((nb_pad,), -1, np.int32)
        boundary_ulocal = np.full((nb_pad,), -1, np.int32)
        boundary_vlocal = np.full((nb_pad,), -1, np.int32)
        boundary_u[slot_of] = ub
        boundary_v[slot_of] = vb
        boundary_index[slot_of] = bsel.astype(np.int32)
        cross = pu != pv
        boundary_ulocal[slot_of] = (ub - pu * window).astype(np.int32)
        boundary_vlocal[slot_of] = (
            vb - pv * window + np.where(cross, window, 0)
        ).astype(np.int32)
        # per-tile pair block ids (every tile sits inside one pair group)
        nb_tiles = nb_pad // tile_size
        blk_of_pair_tile = np.repeat(
            np.arange(len(sizes)), padded_sizes // tile_size
        )
        boundary_blk_u = pu[starts_b[:-1]][blk_of_pair_tile].astype(np.int32)
        boundary_blk_v = pv[starts_b[:-1]][blk_of_pair_tile].astype(np.int32)
        assert boundary_blk_u.shape == (nb_tiles,)
    else:
        nb_pad = 0
        boundary_u = boundary_v = boundary_index = np.zeros((0,), np.int32)
        boundary_ulocal = boundary_vlocal = np.zeros((0,), np.int32)
        boundary_blk_u = boundary_blk_v = np.zeros((0,), np.int32)

    # stream -> decision-slot gather map (see WindowSchedule.stream_src)
    slots_flat = num_rows * slots
    stream_src = np.full((m,), slots_flat + nb_pad, np.int32)
    rr, ss = np.nonzero(edge_index >= 0)
    stream_src[edge_index[rr, ss]] = (rr * slots + ss).astype(np.int32)
    if nb:
        stream_src[bsel] = (slots_flat + slot_of).astype(np.int32)

    return WindowSchedule(
        window=window,
        tile_size=tile_size,
        num_windows=num_windows,
        tiles_per_window=tiles_per_window,
        num_vertices=n,
        num_edges=m,
        u_tiles=u_tiles,
        v_tiles=v_tiles,
        edge_index=edge_index,
        boundary_u=boundary_u,
        boundary_v=boundary_v,
        boundary_index=boundary_index,
        boundary_ulocal=boundary_ulocal,
        boundary_vlocal=boundary_vlocal,
        boundary_blk_u=boundary_blk_u,
        boundary_blk_v=boundary_blk_v,
        window_ids=dense_ids.astype(np.int32),
        reorder=reorder,
        perm=perm,
        inv=inv,
        num_valid=int(valid.sum()),
        num_intra=int(intra.sum()),
        num_windowed=int(windowed.sum()),
        stream_src=stream_src,
    )
