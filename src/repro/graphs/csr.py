"""COO <-> CSR conversion, symmetrization, dedup.

CSR is needed by the vertex-centric EMS/SIDMM baselines (the paper's
competitors require the symmetrized CSR; Skipper itself does not — §V-C).
Host-side (numpy): this is data-loading work, not device compute.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.types import EdgeList, CSRGraph


def dedup_edges(edges: EdgeList, drop_self_loops: bool = True) -> EdgeList:
    """Canonicalize (u<=v), drop duplicates (and optionally self loops)."""
    u, v = edges.to_numpy()
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    if drop_self_loops:
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
    key = lo.astype(np.int64) * np.int64(edges.num_vertices) + hi
    _, idx = np.unique(key, return_index=True)
    return EdgeList(
        jnp.asarray(lo[idx], jnp.int32),
        jnp.asarray(hi[idx], jnp.int32),
        edges.num_vertices,
    )


def symmetrize(edges: EdgeList) -> EdgeList:
    """Return the edge list with both directions present (for CSR baselines)."""
    u, v = edges.to_numpy()
    uu = np.concatenate([u, v])
    vv = np.concatenate([v, u])
    return EdgeList(jnp.asarray(uu, jnp.int32), jnp.asarray(vv, jnp.int32), edges.num_vertices)


def edges_to_csr(edges: EdgeList, symmetric: bool = True) -> CSRGraph:
    e = symmetrize(edges) if symmetric else edges
    u, v = e.to_numpy()
    n = e.num_vertices
    order = np.argsort(u, kind="stable")
    u_sorted = u[order]
    v_sorted = v[order]
    counts = np.bincount(u_sorted, minlength=n)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSRGraph(
        jnp.asarray(offsets, jnp.int32),
        jnp.asarray(v_sorted, jnp.int32),
        n,
    )
