"""Locality-aware vertex reordering — the windowed pipeline's front door.

The device-resident pipeline (`kernels/skipper_match/ops.py`) only pays off
when edges land *inside* a vertex window: permuted RMAT leaves ~13% of edges
intra-window at window=2048, so most work used to fall through to the serial
boundary epilogue (benchmarks/baseline_small.json, DESIGN.md §2 A7). The
paper's locality phase assumes the input order concentrates work; Birn et
al. (*Efficient Parallel and External Matching*) make the same point for
cache-local edge orders. This module makes that a first-class, measured
subsystem: renumber vertices so that edge endpoints cluster into windows,
run the pipeline in the renumbered space, and map results back.

Three pluggable policies (all host/numpy one-shot precompute, like the
window schedule itself):

* ``degree`` — bucket vertices by descending degree. RMAT/power-law hubs are
  rich-club connected (hub-hub edges dominate), so packing hubs into the
  same windows recovers most of the structure the Graph500 permutation
  destroyed. O(V + E), the default. Measured: rmat14 intra 0.13 -> ~0.68.
* ``bfs``    — breadth-first clustering from highest-degree unvisited roots;
  neighbors get nearby ids. Good for meshes/communities (grid-like inputs),
  weaker on scale-free graphs (frontiers explode past window size).
* ``greedy`` — window-affinity clustering: seed each window with the
  highest-degree unassigned vertex, then repeatedly pull in the unassigned
  vertex with the most edges into the window under construction
  (score+degree tie-break). Best intra fractions. The selection runs on a
  lazy-deletion max-heap of affinity-touched candidates merged with a
  degree-order cursor for the untouched ones — O((V + E) log E) total,
  paper-scale ready — and picks the exact vertex the old full
  O(V^2/window) host argmax picked (``_reorder_greedy_argmax``, kept as
  the test oracle, is pinned bit-identical on every generator family).

A ``Reordering`` is a bijection old->new (``perm``) with its inverse
(``inv``); ``windows.build_window_schedule(reorder=...)`` applies it before
bucketing and carries it through the schedule so ``skipper_match`` returns
results in *original* vertex ids.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.types import EdgeList

POLICIES = ("none", "degree", "bfs", "greedy")


@dataclasses.dataclass(frozen=True)
class Reordering:
    """Vertex renumbering: ``perm[old_id] = new_id``, ``inv[new_id] = old_id``.
    Both int32[num_vertices]; ``perm[inv] == inv[perm] == arange``."""

    policy: str
    perm: np.ndarray
    inv: np.ndarray

    @property
    def num_vertices(self) -> int:
        return int(self.perm.shape[0])


def _valid_endpoints(edges: EdgeList):
    u = np.asarray(edges.u)
    v = np.asarray(edges.v)
    valid = (u >= 0) & (v >= 0) & (u != v)
    return u[valid], v[valid]


def _degrees(edges: EdgeList) -> np.ndarray:
    u, v = _valid_endpoints(edges)
    n = edges.num_vertices
    return np.bincount(u, minlength=n) + np.bincount(v, minlength=n)


def _csr_neighbors(edges: EdgeList):
    """Symmetrized CSR (starts int64[n+1], nbrs int[sum deg]) — host numpy."""
    u, v = _valid_endpoints(edges)
    n = edges.num_vertices
    su = np.concatenate([u, v])
    sv = np.concatenate([v, u])
    order = np.argsort(su, kind="stable")
    su = su[order]
    sv = sv[order]
    starts = np.searchsorted(su, np.arange(n + 1))
    return starts, sv


def _from_inverse(policy: str, inv: np.ndarray) -> Reordering:
    n = inv.shape[0]
    perm = np.empty(n, np.int64)
    perm[inv] = np.arange(n)
    return Reordering(policy, perm.astype(np.int32), inv.astype(np.int32))


def _reorder_degree(edges: EdgeList) -> Reordering:
    deg = _degrees(edges)
    inv = np.argsort(-deg, kind="stable")  # new id j <- old vertex inv[j]
    return _from_inverse("degree", inv)


def _reorder_bfs(edges: EdgeList) -> Reordering:
    from collections import deque

    n = edges.num_vertices
    deg = _degrees(edges)
    starts, nbrs = _csr_neighbors(edges)
    roots = np.argsort(-deg, kind="stable")
    visited = np.zeros(n, bool)
    inv = np.empty(n, np.int64)
    pos = 0
    for r in roots:
        if visited[r]:
            continue
        visited[r] = True
        q = deque([int(r)])
        while q:
            x = q.popleft()
            inv[pos] = x
            pos += 1
            for y in nbrs[starts[x] : starts[x + 1]]:
                if not visited[y]:
                    visited[y] = True
                    q.append(int(y))
    assert pos == n
    return _from_inverse("bfs", inv)


def _reorder_greedy_argmax(edges: EdgeList, window: int) -> Reordering:
    """Reference greedy clustering: full argmax over all vertices per pick.

    O(V^2/window) host work — kept ONLY as the test oracle pinning
    :func:`_reorder_greedy`'s heap selection (bit-identical output); the
    production path below is the scalable one.
    """
    n = edges.num_vertices
    deg = _degrees(edges)
    starts, nbrs = _csr_neighbors(edges)
    deg_order = np.argsort(-deg, kind="stable")
    # fractional degree tie-break keeps hub pull without outweighing affinity
    key = deg.astype(np.float64) / (deg.max() + 1.0) * 0.5 if n else deg
    assigned = np.zeros(n, bool)
    score = np.zeros(n, np.float64)
    inv = np.empty(n, np.int64)
    pos = 0
    seed_cursor = 0
    num_windows = -(-n // window)
    for _ in range(num_windows):
        score[:] = 0.0
        while seed_cursor < n and assigned[deg_order[seed_cursor]]:
            seed_cursor += 1
        if seed_cursor >= n:
            break
        cur = int(deg_order[seed_cursor])
        for _ in range(min(window, n - pos)):
            assigned[cur] = True
            inv[pos] = cur
            pos += 1
            np.add.at(score, nbrs[starts[cur] : starts[cur + 1]], 1.0)
            masked = np.where(assigned, -np.inf, score + key)
            cur = int(np.argmax(masked))
    assert pos == n
    return _from_inverse("greedy", inv)


def _reorder_greedy(edges: EdgeList, window: int) -> Reordering:
    """Heap-based greedy clustering, selection-identical to the argmax
    reference but O((V + E) log E).

    The argmax over ``score + key`` decomposes into two candidate pools:

    * vertices *touched* this window (``score > 0``) — kept in a
      lazy-deletion max-heap: every score increment pushes a fresh
      ``(-(score+key), v)`` entry; a popped entry is discarded when the
      vertex is assigned or its stored priority no longer equals the live
      ``score[v] + key[v]`` (per-window score resets make stale entries
      self-invalidate the same way).
    * *untouched* vertices (``score == 0``), whose priority is ``key``
      alone — monotone along the degree order, so the best one is always
      the first unassigned vertex under a monotone cursor. When that
      vertex HAS been touched it also sits in the heap with a strictly
      higher priority (score >= 1 > key), so skipping the untouched pool
      behind it never changes the argmax.

    Ties resolve to the smallest vertex id in both pools — exactly
    ``np.argmax``'s first-maximum rule — so the produced ordering is
    bit-identical to the reference (test-pinned).
    """
    import heapq

    n = edges.num_vertices
    deg = _degrees(edges)
    starts_a, nbrs_a = _csr_neighbors(edges)
    deg_order = np.argsort(-deg, kind="stable").tolist()
    keys_np = (
        deg.astype(np.float64) / (deg.max() + 1.0) * 0.5
        if n
        else deg.astype(np.float64)
    )
    key = keys_np.tolist()
    starts = starts_a.tolist()
    nbrs = nbrs_a.tolist()
    assigned = bytearray(n)
    score = [0.0] * n
    inv = np.empty(n, np.int64)
    pos = 0
    cursor = 0  # first-unassigned pointer into deg_order (seeds AND picks)
    num_windows = -(-n // window)
    for _ in range(num_windows):
        while cursor < n and assigned[deg_order[cursor]]:
            cursor += 1
        if cursor >= n:
            break
        cur = deg_order[cursor]
        heap: list = []
        touched: list = []
        for _ in range(min(window, n - pos)):
            assigned[cur] = True
            inv[pos] = cur
            pos += 1
            for y in nbrs[starts[cur] : starts[cur + 1]]:
                if assigned[y]:
                    continue
                s = score[y] + 1.0
                score[y] = s
                touched.append(y)
                heapq.heappush(heap, (-(s + key[y]), y))
            # best touched candidate (discard assigned/stale entries)
            while heap:
                p, y = heap[0]
                if assigned[y] or -p != score[y] + key[y]:
                    heapq.heappop(heap)
                    continue
                break
            while cursor < n and assigned[deg_order[cursor]]:
                cursor += 1
            if cursor >= n:
                break  # every vertex assigned — no next pick to compute
            d = deg_order[cursor]
            pd = score[d] + key[d]
            if heap:
                p, y = heap[0]
                if (-p, -y) > (pd, -d):
                    cur = y
                    continue
            cur = d
        # reset this window's scores (touched vertices only — O(touched))
        for y in touched:
            score[y] = 0.0
    assert pos == n
    return _from_inverse("greedy", inv)


def reorder_vertices(
    edges: EdgeList, policy: str, window: int = 2048
) -> Reordering:
    """Compute a locality reordering of ``edges``'s vertices.

    ``window`` is the target window size — only the ``greedy`` policy uses it
    (its clusters are window-sized by construction). ``none`` returns the
    identity (handy for uniform benchmarking code paths).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown reorder policy {policy!r}; one of {POLICIES}")
    if policy == "none":
        ident = np.arange(edges.num_vertices, dtype=np.int32)
        return Reordering("none", ident, ident.copy())
    if policy == "degree":
        return _reorder_degree(edges)
    if policy == "bfs":
        return _reorder_bfs(edges)
    return _reorder_greedy(edges, window)


def intra_window_fraction(edges: EdgeList, window: int, reordering=None) -> float:
    """Fraction of valid edges with both endpoints in one window (diagnostic;
    the schedule reports the same number for its own build)."""
    u, v = _valid_endpoints(edges)
    if u.size == 0:
        return 1.0
    if reordering is not None:
        u = reordering.perm[u]
        v = reordering.perm[v]
    return float(np.mean(u // window == v // window))
