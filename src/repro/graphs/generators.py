"""Deterministic graph generators used by tests and benchmarks.

The paper evaluates on web / social / bio / synthetic (Graph500 RMAT) graphs.
We cannot ship 224-billion-edge crawls; we reproduce the *structural families*:

* ``rmat_graph``       — Graph500-style RMAT (the paper's g500 dataset family);
                         skewed, high-locality-violating degree distribution.
* ``erdos_renyi_graph``— uniform random (low-locality baseline).
* ``grid_graph``       — 2-D lattice (high-locality; consecutive-id neighbors),
                         the adversarial case for the thread-dispersed scheduler.
* ``ring_graph`` / ``path_graph`` / ``star_graph`` — worst cases for greedy
                         matching and conflict behaviour.
* ``bipartite_graph``  — token-expert style bipartite graphs for the MoE router.

All generators are numpy-based (host-side data pipeline work, as loading is in
the real system) and deterministic given ``seed``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.types import EdgeList


def _as_edgelist(u: np.ndarray, v: np.ndarray, n: int) -> EdgeList:
    return EdgeList(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32), int(n))


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    permute: bool = True,
) -> EdgeList:
    """Graph500 RMAT generator (Murphy et al., "Introducing the Graph 500").

    ``2**scale`` vertices, ``edge_factor * 2**scale`` edges. Probabilities
    (a,b,c,d) follow the Graph500 spec defaults.
    """
    n = 1 << scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    u = np.zeros(m, dtype=np.int64)
    v = np.zeros(m, dtype=np.int64)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for bit in range(scale):
        coin1 = rng.random(m)
        coin2 = rng.random(m)
        u_bit = coin1 > ab
        v_bit = np.where(
            u_bit, coin2 > c_norm, coin2 > a_norm
        )
        u |= u_bit.astype(np.int64) << bit
        v |= v_bit.astype(np.int64) << bit
    if permute:
        perm = rng.permutation(n)
        u = perm[u]
        v = perm[v]
    return _as_edgelist(u.astype(np.int32), v.astype(np.int32), n)


def erdos_renyi_graph(num_vertices: int, num_edges: int, seed: int = 0) -> EdgeList:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    v = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    return _as_edgelist(u, v, num_vertices)


def grid_graph(rows: int, cols: int) -> EdgeList:
    """2-D lattice with row-major vertex ids — maximal locality."""
    idx = np.arange(rows * cols, dtype=np.int64).reshape(rows, cols)
    right_u = idx[:, :-1].ravel()
    right_v = idx[:, 1:].ravel()
    down_u = idx[:-1, :].ravel()
    down_v = idx[1:, :].ravel()
    u = np.concatenate([right_u, down_u])
    v = np.concatenate([right_v, down_v])
    return _as_edgelist(u, v, rows * cols)


def ring_graph(num_vertices: int) -> EdgeList:
    u = np.arange(num_vertices, dtype=np.int64)
    v = (u + 1) % num_vertices
    return _as_edgelist(u, v, num_vertices)


def path_graph(num_vertices: int) -> EdgeList:
    u = np.arange(num_vertices - 1, dtype=np.int64)
    return _as_edgelist(u, u + 1, num_vertices)


def star_graph(num_leaves: int) -> EdgeList:
    """Vertex 0 connected to all others. MM size is exactly 1 — every edge
    conflicts on the hub, the adversarial case for parallel matchers."""
    u = np.zeros(num_leaves, dtype=np.int64)
    v = np.arange(1, num_leaves + 1, dtype=np.int64)
    return _as_edgelist(u, v, num_leaves + 1)


def bipartite_graph(
    left: int, right: int, num_edges: int, seed: int = 0
) -> EdgeList:
    """Random bipartite graph; left vertices are [0,left), right vertices are
    [left, left+right). Used by the MoE matching-router tests."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, left, size=num_edges, dtype=np.int64)
    v = left + rng.integers(0, right, size=num_edges, dtype=np.int64)
    return _as_edgelist(u, v, left + right)
