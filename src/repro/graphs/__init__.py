"""Graph substrate: generators, CSR utilities, and edge partitioning.

Graphs are represented in COO form as an ``EdgeList`` (two int32 arrays ``u``,
``v`` plus ``num_vertices``) — the natural input format for Skipper, which the
paper notes needs neither symmetrization nor CSR (Section V-C, "Input Format &
Symmetrization"). CSR conversion is provided for the SIDMM/EMS baselines that
are vertex-centric.
"""
from repro.graphs.types import EdgeList, CSRGraph
from repro.graphs.generators import (
    rmat_graph,
    erdos_renyi_graph,
    grid_graph,
    ring_graph,
    star_graph,
    bipartite_graph,
    path_graph,
)
from repro.graphs.csr import edges_to_csr, symmetrize, dedup_edges
from repro.graphs.partition import (
    DeviceSchedule,
    contiguous_chunks,
    dispersed_blocks,
    locality_device_schedule,
    pad_edges,
    partition_schedule,
)
from repro.graphs.reorder import (
    Reordering,
    intra_window_fraction,
    reorder_vertices,
)
from repro.graphs.windows import WindowSchedule, build_window_schedule

__all__ = [
    "EdgeList",
    "CSRGraph",
    "rmat_graph",
    "erdos_renyi_graph",
    "grid_graph",
    "ring_graph",
    "star_graph",
    "bipartite_graph",
    "path_graph",
    "edges_to_csr",
    "symmetrize",
    "dedup_edges",
    "DeviceSchedule",
    "dispersed_blocks",
    "locality_device_schedule",
    "pad_edges",
    "partition_schedule",
    "contiguous_chunks",
    "Reordering",
    "reorder_vertices",
    "intra_window_fraction",
    "WindowSchedule",
    "build_window_schedule",
]
