"""Thread-dispersed locality-preserving edge scheduling (paper §IV-C).

The paper divides the edge stream into blocks of ~equal size and deals them to
threads round-robin: thread t gets blocks t, t+T, t+2T, ... so that (i) each
thread scans *consecutive* edges inside a block (locality-preserving) while
(ii) concurrently-active blocks are far apart in vertex-id space
(thread-dispersed), making JIT conflicts Θ(λ²)-rare.

On TPU the "threads" are devices. ``dispersed_blocks`` reshapes a padded edge
list into [num_devices, num_rounds, block_size] so that round r of device d is
block ``r * D + d`` of the original stream — the exact round-robin deal. The
distributed matcher (core/distributed.py) then scans rounds with devices in
lockstep.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graphs.types import EdgeList, INVALID


def pad_edges(edges: EdgeList, multiple: int) -> EdgeList:
    """Pad edge arrays to a multiple with inert self-loop sentinels."""
    m = edges.num_edges
    target = ((m + multiple - 1) // multiple) * multiple
    if target == m:
        return edges
    pad = target - m
    u = jnp.concatenate([edges.u, jnp.full((pad,), INVALID, jnp.int32)])
    v = jnp.concatenate([edges.v, jnp.full((pad,), INVALID, jnp.int32)])
    return EdgeList(u, v, edges.num_vertices)


def dispersed_blocks(
    edges: EdgeList, num_devices: int, block_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deal edge blocks round-robin to devices.

    Returns (u_blocks, v_blocks) of shape [num_devices, num_rounds, block_size]
    where blocks are assigned ``block_index % num_devices -> device`` — the
    paper's contiguous deal: device d holds blocks d, d+D, d+2D, ...
    (equivalently: round r of device d is original block r*D + d).
    """
    padded = pad_edges(edges, num_devices * block_size)
    total = padded.num_edges
    num_blocks = total // block_size
    num_rounds = num_blocks // num_devices
    # [num_blocks, block_size] -> [num_rounds, num_devices, block_size]
    ub = padded.u.reshape(num_rounds, num_devices, block_size)
    vb = padded.v.reshape(num_rounds, num_devices, block_size)
    # -> [num_devices, num_rounds, block_size]
    return jnp.swapaxes(ub, 0, 1), jnp.swapaxes(vb, 0, 1)


def contiguous_chunks(
    edges: EdgeList, num_chunks: int
) -> Tuple[jax.Array, jax.Array]:
    """Split into equal contiguous chunks (the *non*-dispersed baseline used to
    show the scheduler matters). Returns device arrays of shape
    [num_chunks, ceil(m / num_chunks)], padded with INVALID."""
    padded = pad_edges(edges, num_chunks)
    per = padded.num_edges // num_chunks
    u = padded.u.reshape(num_chunks, per)
    v = padded.v.reshape(num_chunks, per)
    return u, v
