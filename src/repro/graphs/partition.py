"""Thread-dispersed and locality-sharded edge scheduling (paper §IV-C).

The paper divides the edge stream into blocks of ~equal size and deals them to
threads round-robin: thread t gets blocks t, t+T, t+2T, ... so that (i) each
thread scans *consecutive* edges inside a block (locality-preserving) while
(ii) concurrently-active blocks are far apart in vertex-id space
(thread-dispersed), making JIT conflicts Θ(λ²)-rare.

On TPU the "threads" are devices. ``dispersed_blocks`` reshapes a padded edge
list into [num_devices, num_rounds, block_size] so that round r of device d is
block ``r * D + d`` of the original stream — the exact round-robin deal. The
distributed matcher (core/distributed.py) then scans rounds with devices in
lockstep.

``partition_schedule`` is the *locality-sharded* deal: instead of raw stream
blocks it partitions a two-tier ``WindowSchedule`` (optionally built behind a
``graphs/reorder.py`` renumbering) across devices. Windows are disjoint
vertex-id ranges, so each device resolves its windows entirely locally — no
proposals, no replay, zero collective payload — through the device-resident
pipeline; only the global tier (cross-window + coalesced sparse-window edges)
still needs the propose/gather/replay protocol, and it is dealt round-robin
exactly like ``dispersed_blocks``. Birn et al. (*Efficient Parallel and
External Matching*) motivate exactly this: locality-preserving edge placement
is what makes block-parallel greedy matching scale. The schedule's
``perm``/``inv`` and ``stream_src`` ride along so the distributed driver
returns masks in original stream order and states in original vertex ids.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.types import EdgeList, INVALID
from repro.graphs.windows import WindowSchedule, build_window_schedule


def pad_edges(edges: EdgeList, multiple: int) -> EdgeList:
    """Pad edge arrays to a multiple with inert self-loop sentinels."""
    m = edges.num_edges
    target = ((m + multiple - 1) // multiple) * multiple
    if target == m:
        return edges
    pad = target - m
    u = jnp.concatenate([edges.u, jnp.full((pad,), INVALID, jnp.int32)])
    v = jnp.concatenate([edges.v, jnp.full((pad,), INVALID, jnp.int32)])
    return EdgeList(u, v, edges.num_vertices)


def dispersed_blocks(
    edges: EdgeList,
    num_devices: int,
    block_size: int,
    reorder: str = "none",
    window: Optional[int] = None,
    tile_size: int = 256,
):
    """Deal edge blocks round-robin to devices.

    Returns (u_blocks, v_blocks) of shape [num_devices, num_rounds, block_size]
    where blocks are assigned ``block_index % num_devices -> device`` — the
    paper's contiguous deal: device d holds blocks d, d+D, d+2D, ...
    (equivalently: round r of device d is original block r*D + d).

    Passing ``reorder=`` (a ``graphs/reorder.py`` policy) and/or ``window=``
    switches to the *locality-sharded* mode: the edges are renumbered,
    bucketed into a two-tier ``WindowSchedule``, and partitioned so each
    device's round is dominated by intra-window edges it can resolve with
    zero communication. That mode returns a :class:`DeviceSchedule` (which
    carries the perm/inv + stream-index round-trip) instead of the raw block
    pair — see :func:`partition_schedule` for the layout.
    """
    if reorder != "none" or window is not None:
        return locality_device_schedule(
            edges, num_devices, block_size,
            window=window, tile_size=tile_size, reorder=reorder,
        )
    padded = pad_edges(edges, num_devices * block_size)
    total = padded.num_edges
    num_blocks = total // block_size
    num_rounds = num_blocks // num_devices
    # [num_blocks, block_size] -> [num_rounds, num_devices, block_size]
    ub = padded.u.reshape(num_rounds, num_devices, block_size)
    vb = padded.v.reshape(num_rounds, num_devices, block_size)
    # -> [num_devices, num_rounds, block_size]
    return jnp.swapaxes(ub, 0, 1), jnp.swapaxes(vb, 0, 1)


def locality_device_schedule(
    edges: EdgeList,
    num_devices: int,
    block_size: int,
    *,
    window: Optional[int] = None,
    tile_size: int = 256,
    reorder: str = "none",
    schedule: Optional["WindowSchedule"] = None,
) -> "DeviceSchedule":
    """Build (or take) a two-tier window schedule and partition it across
    devices — the one place the locality-sharded mode builds schedules on a
    caller's behalf (``dispersed_blocks(reorder=...)`` and
    ``distributed_skipper`` both route through here). ``window=None``
    defers to ``build_window_schedule``'s own default."""
    if schedule is None:
        kwargs = {} if window is None else {"window": window}
        schedule = build_window_schedule(
            edges, tile_size=tile_size, reorder=reorder, **kwargs
        )
    return partition_schedule(schedule, num_devices, block_size)


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """Locality-sharded deal of a :class:`WindowSchedule` across devices.

    The window tier: schedule rows (dense windows) are dealt whole to devices
    with an LPT greedy (descending edge count -> least-loaded device), padded
    to ``rows_per_device`` with empty (-1) rows. Windows are disjoint vertex
    ranges, so a device resolves its rows with no communication, and the
    result per row is independent of WHICH device ran it (tests pin this).

    The global tier: the schedule's boundary stream (renumbered GLOBAL ids,
    stream order) dealt round-robin into [num_devices, num_rounds,
    block_size] blocks exactly like ``dispersed_blocks``; at D=1 this
    degenerates to the stream in order, which keeps the single-device
    distributed run bit-identical to ``skipper_match`` on the same schedule.

    All arrays are host numpy; the driver moves them to device at trace time.
    """

    schedule: WindowSchedule
    num_devices: int
    block_size: int
    u_rows: np.ndarray     # int32[D, rows_per_device, tpw * tile], local ids
    v_rows: np.ndarray
    row_slot: np.ndarray   # int32[D, rows_per_device] schedule-row idx, -1 pad
    boundary_ub: np.ndarray  # int32[D, R, B] global-tier deal, global ids
    boundary_vb: np.ndarray
    boundary_ib: np.ndarray  # int32[D, R, B] boundary stream position, -1 pad

    @property
    def rows_per_device(self) -> int:
        return int(self.u_rows.shape[1])

    @property
    def num_rounds(self) -> int:
        return int(self.boundary_ub.shape[1])

    @property
    def intra_fraction(self) -> float:
        return self.schedule.intra_fraction

    @property
    def windowed_fraction(self) -> float:
        return self.schedule.windowed_fraction

    @property
    def window_balance(self) -> float:
        """max/mean windowed edges per device (1.0 = perfectly balanced)."""
        per_dev = (self.u_rows >= 0).sum(axis=(1, 2))
        mean = per_dev.mean()
        return float(per_dev.max() / mean) if mean else 1.0


def partition_schedule(
    schedule: WindowSchedule, num_devices: int, block_size: int
) -> DeviceSchedule:
    """Deal a two-tier window schedule to devices (see DeviceSchedule).

    ``block_size`` must be a multiple of the schedule's ``tile_size`` so the
    global-tier slab tiles of every device line up with the boundary
    epilogue's tiles (that alignment is what makes D=1 bit-identical to
    ``skipper_match``).
    """
    if block_size % schedule.tile_size != 0:
        raise ValueError(
            f"block_size {block_size} must be a multiple of tile_size "
            f"{schedule.tile_size} (slab tiles must align with the boundary "
            "epilogue's)"
        )
    d = int(num_devices)
    num_rows = schedule.num_rows
    slots = schedule.tiles_per_window * schedule.tile_size

    # --- window tier: LPT deal of rows by valid-edge count ---------------
    counts = (schedule.edge_index >= 0).sum(axis=1)
    order = np.argsort(-counts, kind="stable")
    loads = np.zeros(d, np.int64)
    rows_of = [[] for _ in range(d)]
    for r in order:
        dev = int(np.argmin(loads))  # ties -> lowest device id
        rows_of[dev].append(int(r))
        loads[dev] += int(counts[r])
    rows_per_device = max(1, max(len(rs) for rs in rows_of))
    u_rows = np.full((d, rows_per_device, slots), -1, np.int32)
    v_rows = np.full((d, rows_per_device, slots), -1, np.int32)
    row_slot = np.full((d, rows_per_device), -1, np.int32)
    for dev, rs in enumerate(rows_of):
        rs = sorted(rs)  # ascending schedule-row order within a device
        if rs:
            row_slot[dev, : len(rs)] = rs
            u_rows[dev, : len(rs)] = schedule.u_tiles[rs]
            v_rows[dev, : len(rs)] = schedule.v_tiles[rs]

    # --- global tier: round-robin block deal of the boundary stream ------
    nb_pad = schedule.num_boundary_padded
    per_round = d * block_size
    total_b = -(-max(nb_pad, 1) // per_round) * per_round if nb_pad else 0
    bu = np.full((total_b,), -1, np.int32)
    bv = np.full((total_b,), -1, np.int32)
    bi = np.full((total_b,), -1, np.int32)
    if nb_pad:
        bu[:nb_pad] = schedule.boundary_u
        bv[:nb_pad] = schedule.boundary_v
        real = schedule.boundary_index >= 0
        bi[:nb_pad] = np.where(real, np.arange(nb_pad, dtype=np.int32), -1)
    num_rounds = total_b // per_round if nb_pad else 0
    shape = (num_rounds, d, block_size)
    boundary_ub = np.swapaxes(bu.reshape(shape), 0, 1)
    boundary_vb = np.swapaxes(bv.reshape(shape), 0, 1)
    boundary_ib = np.swapaxes(bi.reshape(shape), 0, 1)

    return DeviceSchedule(
        schedule=schedule,
        num_devices=d,
        block_size=block_size,
        u_rows=u_rows,
        v_rows=v_rows,
        row_slot=row_slot,
        boundary_ub=boundary_ub,
        boundary_vb=boundary_vb,
        boundary_ib=boundary_ib,
    )


def contiguous_chunks(
    edges: EdgeList, num_chunks: int
) -> Tuple[jax.Array, jax.Array]:
    """Split into equal contiguous chunks (the *non*-dispersed baseline used to
    show the scheduler matters). Returns device arrays of shape
    [num_chunks, ceil(m / num_chunks)], padded with INVALID."""
    padded = pad_edges(edges, num_chunks)
    per = padded.num_edges // num_chunks
    u = padded.u.reshape(num_chunks, per)
    v = padded.v.reshape(num_chunks, per)
    return u, v
