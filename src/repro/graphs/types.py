"""Core graph container types.

``EdgeList`` is a pytree so it can flow through jit/shard_map boundaries.
Invalid (padding) edges are encoded as ``u == v == INVALID`` and are skipped by
every matcher (the paper skips self-loops anyway, Alg. 1 lines 6-7, so padding
with self-loops at a reserved vertex is free).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel vertex id used for padding edges. Matchers skip self-loops, so a
# padding edge (INVALID, INVALID) is inert.
INVALID = np.int32(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgeList:
    """COO edge list. ``u`` and ``v`` are int32 arrays of equal length."""

    u: jax.Array
    v: jax.Array
    num_vertices: int  # static

    def tree_flatten(self):
        return (self.u, self.v), (self.num_vertices,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def num_edges(self) -> int:
        return int(self.u.shape[0])

    def canonical(self) -> "EdgeList":
        """Return with u <= v per edge (paper Alg.1 lines 8-9: min/max)."""
        lo = jnp.minimum(self.u, self.v)
        hi = jnp.maximum(self.u, self.v)
        return EdgeList(lo, hi, self.num_vertices)

    def to_numpy(self) -> Tuple[np.ndarray, np.ndarray]:
        return np.asarray(self.u), np.asarray(self.v)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed sparse row graph (paper §II-A).

    offsets: int32[|V|+1]; neighbors: int32[|E|].
    """

    offsets: jax.Array
    neighbors: jax.Array
    num_vertices: int

    def tree_flatten(self):
        return (self.offsets, self.neighbors), (self.num_vertices,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def num_edges(self) -> int:
        return int(self.neighbors.shape[0])

    def degree(self, v: int) -> int:
        return int(self.offsets[v + 1] - self.offsets[v])
