"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    num_experts=8, num_experts_per_tok=2, moe_router="skipper",
    sliding_window=4096, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    num_experts=4, num_experts_per_tok=2, moe_router="skipper",
    sliding_window=32, dtype="float32", remat=False,
)
