"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783; unverified]
opt_state_dtype=bf16: at 405B params, f32 Adam moments alone exceed a
256-chip v5e pod's HBM; bf16 moments (the production trick, cf. FSDP
implementations with 16-bit optimizer state) bring train_4k under budget
(dry-run memory analysis in EXPERIMENTS.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    rope_theta=5e5, opt_state_dtype="bfloat16", seq_sharded_residual=True,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke", family="dense",
    num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, dtype="float32", remat=False,
)
