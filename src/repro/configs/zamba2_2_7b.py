"""zamba2-2.7b [hybrid] — 54 Mamba-2 layers d_model=2560, one SHARED
attention block (32H MHA + d_ff=10240 MLP) applied every 6 layers,
ssm_state=64, vocab=32000. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=64,
    shared_attn_period=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
    shared_attn_period=2, dtype="float32", remat=False,
)
