from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, SHAPES
from repro.configs.registry import (
    ARCH_IDS,
    get_config,
    get_smoke_config,
    get_shape,
    runnable_cells,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "TrainConfig", "SHAPES",
    "ARCH_IDS", "get_config", "get_smoke_config", "get_shape", "runnable_cells",
]
