"""mamba2-130m [ssm] — 24L d_model=768 attention-free, ssm_state=128,
SSD (state-space duality), vocab=50280, tied embeddings.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256, tie_embeddings=True,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
    dtype="float32", remat=False,
)
