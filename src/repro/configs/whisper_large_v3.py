"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H (MHA)
d_ff=5120 vocab=51866; conv frontend STUBBED to precomputed 1500-frame
embeddings (input_specs). train_4k = 4096 decoder tokens teacher-forced
against the standard 1500-frame encoder. [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, encoder_layers=32, encoder_frames=1500,
    d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    norm="layernorm", act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, encoder_layers=2, encoder_frames=32,
    d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    norm="layernorm", act="gelu", tie_embeddings=True,
    dtype="float32", remat=False,
)
