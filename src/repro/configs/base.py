"""Config schema: model architecture, input shapes, mesh, training."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0      # 0 = full attention
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE freq split (t,h,w)
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_router: str = "skipper"  # skipper (paper technique) | topk
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # --- hybrid (zamba2): one shared attention block every k ssm layers ---
    shared_attn_period: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # --- numerics / memory ---
    dtype: str = "bfloat16"
    remat: bool = True
    # Megatron-style sequence parallelism on the residual stream: the
    # remat-saved per-layer activations are sharded over ("model", seq);
    # each layer all-gathers on entry. Required to fit >=100B dense models.
    seq_sharded_residual: bool = False
    # Adam moment dtype: f32 for <70B, bf16 for huge models (large-scale trick)
    opt_state_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode with a bounded / linear-state cache?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient accumulation
    z_loss: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    grad_compression: str = "none"   # none | bf16 (compressed cross-device psum)
