"""Architecture registry: --arch <id> resolves here.

Each assigned architecture has its own module with the exact published
config; this registry maps ids to (ModelConfig, reduced smoke ModelConfig).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "mixtral-8x7b",
    "qwen2-vl-2b",
    "llama3-405b",
    "qwen1.5-110b",
    "llama3.2-1b",
    "qwen1.5-0.5b",
    "whisper-large-v3",
    "zamba2-2.7b",
    "mamba2-130m",
]

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "llama3-405b": "llama3_405b",
    "qwen1.5-110b": "qwen1_5_110b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def runnable_cells() -> Dict[str, Tuple[str, ...]]:
    """(arch -> shapes) skip matrix: long_500k only for sub-quadratic archs
    (DESIGN.md §6)."""
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = ["train_4k", "prefill_32k", "decode_32k"]
        if cfg.subquadratic:
            shapes.append("long_500k")
        out[arch] = tuple(shapes)
    return out
