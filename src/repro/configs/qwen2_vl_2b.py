"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE + dynamic resolution (vision tower stubbed to
precomputed patch embeddings). [arXiv:2409.12191; hf]
M-RoPE sections (t,h,w) = (16,24,24) over head_dim/2 = 64 freq slots."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, qkv_bias=True,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, qkv_bias=True,
    mrope_sections=(2, 3, 3), dtype="float32", remat=False,
)
