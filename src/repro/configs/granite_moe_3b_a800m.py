"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, MoE 40 experts top-8 (fine-grained experts).
[hf:ibm-granite/granite-3.0-3b-a800m-base family; hf]
Note: the assignment lists "MoE 40e top-8" alongside the 1b-a400m source tag
(32e); we follow the explicit 40e top-8 spec.
Uses the Skipper b-matching router by default — the paper technique as a
first-class MoE feature (DESIGN.md §3)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=40, num_experts_per_tok=8, moe_router="skipper",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=32, vocab_size=256,
    num_experts=8, num_experts_per_tok=2, moe_router="skipper",
    dtype="float32", remat=False,
)
