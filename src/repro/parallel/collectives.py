"""Distributed-optimization collectives helpers.

``compressed_psum`` — bf16 gradient compression for the cross-device
all-reduce: halves the collective bytes of the gradient reduction (the
dominant collective of data-parallel training) at the cost of ~8 mantissa
bits, which AdamW's normalizer absorbs. Selected by
TrainConfig.grad_compression="bf16"; EXPERIMENTS.md §Perf quantifies the
collective-term saving on the hillclimbed cells.

Under jit-with-sharding (our default), gradients are reduced implicitly by
XLA; compression is expressed by casting the gradient pytree to bf16 *before*
the psum boundary (microbatch accumulation loop) and restoring f32 after.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_tree(grads: Any, mode: str) -> Any:
    if mode == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16) if g.dtype == jnp.float32 else g, grads
        )
    return grads


def decompress_tree(grads: Any, mode: str) -> Any:
    if mode == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.float32) if g.dtype == jnp.bfloat16 else g, grads
        )
    return grads


def compressed_psum(grads: Any, axis_name: str, mode: str = "bf16") -> Any:
    """Explicit-collective variant for shard_map code paths."""
    grads = compress_tree(grads, mode)
    grads = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads)
    return decompress_tree(grads, mode)
