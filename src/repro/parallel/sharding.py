"""Parameter & activation sharding rules (FSDP + TP, pod-aware).

Meshes are always ("data", "model") single-pod or ("pod", "data", "model")
multi-pod. Policy:

* ``fsdp`` axes = ("pod", "data") when present, else ("data",): parameters,
  optimizer moments and gradients are fully sharded over them (ZeRO-3 style)
  *in addition to* tensor parallelism over "model" — required to fit >=100B
  models (DESIGN.md §7).
* ``tp`` axis = "model": attention head projections and FFN hidden dim.

Rules are name-based over the param pytree path, so every architecture in the
zoo (dense / MoE / SSM / hybrid / enc-dec) gets a spec without per-model
plumbing. A dim is sharded only when divisible by the axis size — otherwise
the rule degrades to replication for that dim (logged by the dry-run).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat


@dataclasses.dataclass(frozen=True)
class AxisRules:
    fsdp: Tuple[str, ...]        # ("pod","data") or ("data",)
    tp: str                      # "model"
    mesh: Mesh

    def axis_size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        sizes = dict(self.mesh.shape)
        size = 1
        for a in axes:
            size *= sizes[a]
        return size


def rules_for_mesh(mesh: Mesh) -> AxisRules:
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    if not fsdp:
        fsdp = (names[0],)
    tp = "model" if "model" in names else names[-1]
    return AxisRules(fsdp=fsdp, tp=tp, mesh=mesh)


# (regex over param path, spec template) — templates use "F" for fsdp axes,
# "T" for tp, None for replicated; applied right-aligned to the array rank so
# stacked [L, ...] params get a leading None automatically.
_RULES = [
    (r"embed", ("T", "F")),                  # [V, D] vocab over tp
    (r"lm_head", ("F", "T")),                # [D, V]
    (r"(wq|wk|wv|in_proj|w_gate|w_up|dt_proj|cross_wq|enc_wq|enc_wk|enc_wv)$", ("F", "T")),
    (r"(wo|w_down|out_proj|cross_wo|enc_wo)$", ("T", "F")),
    (r"(bq|bk|bv|b_gate|b_up)$", ("T",)),
    (r"(bo|b_down)$", ("F",)),
    (r"router", ("F", None)),                # [D, E] experts replicated
    (r"experts_(gate|up)$", (None, "F", "T")),   # [E, D, F] TP-MoE
    (r"experts_down$", (None, "T", "F")),        # [E, F, D]
    (r"conv_w", (None, None)),               # ssm depthwise conv [W, C]
    (r"(A_log|D_skip|dt_bias|conv_b)", (None,)),
    (r"(norm|scale|bias|ln)", (None,)),
    (r"pos_embed", (None, "F")),
]


def _spec_for(path: str, shape: Tuple[int, ...], rules: AxisRules) -> P:
    for pat, template in _RULES:
        if re.search(pat, path):
            tpl = list(template)
            # right-align template to rank (stacked layer dims lead)
            pad = len(shape) - len(tpl)
            if pad < 0:
                tpl = tpl[-len(shape):] if len(shape) else []
            else:
                tpl = [None] * pad + tpl
            spec = []
            for dim, t in zip(shape, tpl):
                if t == "F":
                    ax = rules.fsdp if len(rules.fsdp) > 1 else rules.fsdp[0]
                    spec.append(ax if dim % rules.axis_size(ax) == 0 else None)
                elif t == "T":
                    spec.append(rules.tp if dim % rules.axis_size(rules.tp) == 0 else None)
                else:
                    spec.append(None)
            return P(*spec)
    return P()  # replicated default


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """Given a pytree of ShapeDtypeStruct (or arrays), produce NamedShardings."""
    rules = rules_for_mesh(mesh)

    def f(path, leaf):
        spec = _spec_for(_path_str(path), leaf.shape, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def param_specs(params_shape: Any, mesh: Mesh) -> Any:
    rules = rules_for_mesh(mesh)

    def f(path, leaf):
        return _spec_for(_path_str(path), leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def constrain(x: jax.Array, spec: Optional[P]) -> jax.Array:
    """Apply a sharding constraint if we are under a mesh context; no-op on a
    bare CPU run (so smoke tests don't need a mesh).

    NB: must pass NamedSharding(abstract_mesh, spec) — the bare-PartitionSpec
    form of with_sharding_constraint silently no-ops on Auto-typed mesh axes
    in this jax version (verified; it cost 30+ GiB of replicated MoE buffers
    before being caught)."""
    if spec is None:
        return x
    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        # drop axes the current mesh doesn't have (uneven dims are fine:
        # with_sharding_constraint pads)
        def _filter(entry):
            if entry is None:
                return None
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in axes if a in mesh.axis_names)
            if not kept:
                return None
            return kept if isinstance(entry, tuple) else kept[0]

        entries = list(spec) + [None] * (x.ndim - len(spec))
        spec2 = P(*[_filter(e) for e in entries])
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec2)
        )
    except Exception:
        return x


def batch_spec(mesh_names: Tuple[str, ...]) -> P:
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_names)
    return P(fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None))
