from repro.parallel.sharding import (
    AxisRules,
    rules_for_mesh,
    param_shardings,
    constrain,
)
from repro.parallel.collectives import compressed_psum

__all__ = [
    "AxisRules",
    "rules_for_mesh",
    "param_shardings",
    "constrain",
    "compressed_psum",
]
