"""Version-compat shims for jax API drift.

The sharding / launch / roofline layers were written against the
``jax.sharding.AxisType`` era (jax >= 0.5); the container ships jax 0.4.37,
which predates ``AxisType``, ``jax.set_mesh``, ``jax.sharding.
get_abstract_mesh``, the ``(shape, names, axis_types=...)`` ``AbstractMesh``
constructor, and returns ``Compiled.cost_analysis()`` as a one-element list.
Every such call site routes through this module so the rest of the codebase
is written once, against the modern surface (ROADMAP "jax version drift").

All shims degrade to the semantically-equivalent legacy API; none of them
changes behaviour on modern jax.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Sequence, Tuple

import jax


def _parse_version(s: str) -> Tuple[int, ...]:
    parts = []
    for p in s.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)

# The single capability probe the mesh shims branch on: AxisType arrived
# together with the explicit-sharding mesh API.
HAS_AXIS_TYPE: bool = hasattr(jax.sharding, "AxisType")


def jax_at_least(*version: int) -> bool:
    """True iff the runtime jax is at least ``version`` (e.g. (0, 5))."""
    return JAX_VERSION >= tuple(version)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on modern jax, ``None`` where it predates
    AxisType (legacy meshes are implicitly fully automatic)."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(shape: Sequence[int], names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types when the kwarg exists."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(shape), tuple(names), axis_types=auto_axis_types(len(names))
        )
    return jax.make_mesh(tuple(shape), tuple(names))


def abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Device-free mesh for shape-only sharding computations.

    Modern jax: ``AbstractMesh(shape, names, axis_types=...)``. jax 0.4.x
    takes a single ``((name, size), ...)`` tuple and no axis types.
    """
    if HAS_AXIS_TYPE:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(names), axis_types=auto_axis_types(len(names))
        )
    return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def set_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on modern jax; on legacy jax a concrete ``Mesh`` is
    itself a context manager that installs the thread-local physical mesh,
    which ``get_abstract_mesh`` below reads back.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The mesh currently in scope (or an empty mesh when none is).

    Modern jax: ``jax.sharding.get_abstract_mesh``. Legacy jax: the
    thread-local physical mesh installed by ``with mesh:`` /
    :func:`set_mesh`. Both expose ``.empty``, ``.axis_names`` and ``.shape``,
    which is all callers use.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    return jax.interpreters.pxla.thread_resources.env.physical_mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` (keyword mesh, ``check_vma``) on modern jax;
    ``jax.experimental.shard_map.shard_map`` (``check_rep``) on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def cost_analysis(compiled) -> Dict[str, Any]:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (0.4.x returns a one-element list of dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca
