from repro.optim.adamw import AdamWState, init_state, apply_updates, cosine_lr, clip_by_global_norm

__all__ = ["AdamWState", "init_state", "apply_updates", "cosine_lr", "clip_by_global_norm"]
