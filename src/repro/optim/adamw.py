"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule,
and configurable moment dtype (bf16 moments for >=100B models — halves
optimizer HBM, the enabling trick for llama3-405b on a 256-chip pod).

Functional optax-style triple (init / update) without the optax dependency —
everything jit-safe and shard-transparent (moments inherit parameter
shardings through the rules in parallel/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any      # first moments  (pytree like params)
    nu: Any      # second moments


def init_state(params: Any, cfg: TrainConfig, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(step: jax.Array, cfg: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def apply_updates(
    params: Any, grads: Any, state: AdamWState, cfg: TrainConfig
) -> Tuple[Any, AdamWState, jax.Array, jax.Array]:
    """Returns (new_params, new_state, lr, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, n):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        nf = n.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / c1
        nhat = nf / c2
        delta = mhat / (jnp.sqrt(nhat) + 1e-8)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mf.astype(m.dtype), nf.astype(n.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_n = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_n = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_n), lr, gnorm
