#!/usr/bin/env python
"""Back-compat shim: the state-dtype lint is now an analyzer rule.

The lint lives in ``src/repro/analysis/rules/state_dtype.py`` (same
logic, same ``# state-dtype: ok`` waiver, same ``core/statespec.py``
exemption) and runs as part of ``tools/analyze.py`` — the CI
``static-analysis`` job replaced the old ``state-dtype-lint`` job. This
shim keeps the historical entry point alive for scripts and muscle
memory: it delegates to the rule and preserves the old output format and
exit codes (0 clean, 1 violations).

Usage: ``python tools/lint_state_dtype.py [paths...]`` — defaults to
``src/repro``.
"""
from __future__ import annotations

import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def main(argv) -> int:
    from repro.analysis.runner import analyze_sources

    paths = argv[1:] or [str(REPO_ROOT / "src" / "repro")]
    report = analyze_sources(paths, rules=["state-dtype"])
    for f in report.findings:
        print(f"{f.where}:{f.lineno}: {f.message}")
    if report.findings:
        print(f"\n{len(report.findings)} state-dtype violation(s).")
        return 1
    print(f"state-dtype lint: {report.files_analyzed} files clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
