#!/usr/bin/env python
"""Lint: no hardcoded vertex-state dtypes outside ``core/statespec.py``.

The state-width refactor (DESIGN.md §12) made ``core/statespec.StateSpec``
the single source of truth for how wide vertex state is at rest, in VMEM,
on the wire, and in counters. A literal ``jnp.int32`` / ``jnp.uint8`` on a
state-array allocation anywhere else silently pins one tier back to a fixed
width and de-synchronizes it from the spec — the exact bug class this
refactor removed. This lint fails CI when such a literal reappears.

What counts as a violation: an allocator call — ``jnp.zeros`` / ``ones`` /
``full`` / ``empty`` / ``*_like``, ``jax.ShapeDtypeStruct``,
``pltpu.VMEM``, or ``.astype`` — whose dtype argument is a literal
``jnp.int32`` / ``jnp.uint8`` / ``np.int32`` / ``np.uint8`` AND whose
context names a state-ish value (the assignment target, or the ``.astype``
receiver, matches ``state* / rebuilt / flat / used_*``). Index math, iota,
stream ids, stats scalars etc. allocate int32 freely — their names don't
match, and ``jnp.asarray`` is never flagged (it wraps Python scalars for
stats, not state).

Escape hatch: a genuine fixed-width site (e.g. a wire-protocol constant)
can carry a ``# state-dtype: ok`` comment on the same line.

Usage: ``python tools/lint_state_dtype.py [paths...]`` — defaults to
``src/repro``. Exit 0 clean, 1 with violations (one per line:
``path:lineno: message``). Stdlib-only by design: the CI job runs it
without installing the package.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_TARGET = REPO_ROOT / "src" / "repro"
EXEMPT = {DEFAULT_TARGET / "core" / "statespec.py"}

WAIVER = "# state-dtype: ok"
DTYPE_LITERALS = {"int32", "uint8"}
DTYPE_MODULES = {"jnp", "np", "numpy", "jax"}
ALLOCATORS = {
    "zeros", "ones", "full", "empty",
    "zeros_like", "ones_like", "full_like", "empty_like",
    "ShapeDtypeStruct", "VMEM", "astype",
}
# Names that denote vertex state (or its aliases through the pipelines):
# the committed state array, the mask-rebuilt state, the flattened
# renumbered state (the bare name ``flat`` — ``slots_flat``/``flat_tok``
# style index names are NOT state), and the capacitated per-side used
# counts.
STATEISH = re.compile(
    r"(?:^|_)(?:state|states|rebuilt|used)(?:$|_|[0-9])|^flat[0-9]*$"
)


def _names_in(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr
        elif isinstance(sub, ast.arg):
            yield sub.arg


def _is_dtype_literal(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr in DTYPE_LITERALS
        and isinstance(node.value, ast.Name)
        and node.value.id in DTYPE_MODULES
    )


def _dtype_literal_in_call(call: ast.Call):
    for arg in call.args:
        if _is_dtype_literal(arg):
            return arg.attr
    for kw in call.keywords:
        if kw.arg == "dtype" and _is_dtype_literal(kw.value):
            return kw.value.attr
    return None


def _allocator_name(call: ast.Call):
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._lint_parent = node  # type: ignore[attr-defined]


def _context_names(call: ast.Call):
    """Names the allocation binds to: walk up to the nearest assignment
    and collect its target identifiers (plus, for ``.astype``, the
    receiver's — ``state.astype(jnp.int32)`` is a state cast wherever the
    result lands)."""
    names = []
    if isinstance(call.func, ast.Attribute) and call.func.attr == "astype":
        names.extend(_names_in(call.func.value))
    node: ast.AST = call
    while node is not None:
        parent = getattr(node, "_lint_parent", None)
        if isinstance(parent, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (
                parent.targets
                if isinstance(parent, ast.Assign)
                else [parent.target]
            )
            for t in targets:
                names.extend(_names_in(t))
            break
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)):
            break
        node = parent
    return names


def lint_file(path: Path):
    source = path.read_text()
    lines = source.splitlines()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:  # a broken file is its own CI failure
        return [(path, exc.lineno or 0, f"syntax error: {exc.msg}")]
    _attach_parents(tree)

    violations = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        alloc = _allocator_name(node)
        if alloc not in ALLOCATORS:
            continue
        dtype = _dtype_literal_in_call(node)
        if dtype is None:
            continue
        if not any(STATEISH.search(n) for n in _context_names(node)):
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if WAIVER in line:
            continue
        violations.append((
            path, node.lineno,
            f"state allocation pins dtype {dtype} via {alloc}() — take the "
            f"width from core/statespec.StateSpec (or waive with "
            f"'{WAIVER}')",
        ))
    return violations


def main(argv) -> int:
    targets = [Path(a) for a in argv[1:]] or [DEFAULT_TARGET]
    files = []
    for t in targets:
        files.extend(sorted(t.rglob("*.py")) if t.is_dir() else [t])
    violations = []
    for f in files:
        if f.resolve() in {p.resolve() for p in EXEMPT}:
            continue
        violations.extend(lint_file(f))
    for path, lineno, msg in violations:
        try:
            shown = path.resolve().relative_to(REPO_ROOT)
        except ValueError:
            shown = path
        print(f"{shown}:{lineno}: {msg}")
    if violations:
        print(f"\n{len(violations)} state-dtype violation(s).")
        return 1
    print(f"state-dtype lint: {len(files)} files clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
