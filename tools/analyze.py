#!/usr/bin/env python
"""Kernel conformance analyzer CLI (see ``src/repro/analysis/``).

Traces every production pallas kernel + jitted entry point to jaxprs on
CPU and runs the full rule battery (Mosaic-lowerability, DMA
happens-before, write-back ordering, VMEM budget / V-independence, tile
geometry, block races, host-sync hygiene, lru cache keys, state dtypes,
deprecated aliases) over them plus the given source roots.

Usage::

    PYTHONPATH=src python tools/analyze.py [paths...]         # default: src/repro
    PYTHONPATH=src python tools/analyze.py src/repro benchmarks examples
    PYTHONPATH=src python tools/analyze.py --json report.json src/repro
    PYTHONPATH=src python tools/analyze.py --targets boundary_kernel
    PYTHONPATH=src python tools/analyze.py --rules state-dtype src/repro
    PYTHONPATH=src python tools/analyze.py --mutation dropped_dma_wait
    PYTHONPATH=src python tools/analyze.py --list

Exit codes: 0 clean, 1 findings at ERROR severity, 2 analyzer crash.
``--mutation`` runs the battery over one seeded mutant — the CI canary
asserts exit code 1 EXACTLY (0 means the analyzer lost its teeth, 2 means
it crashed; both fail the build).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="analyze.py",
        description="static kernel conformance analyzer",
    )
    ap.add_argument("paths", nargs="*",
                    help="source roots/files to lint (default: src/repro)")
    ap.add_argument("--json", metavar="FILE",
                    help="write the JSON report here ('-' for stdout)")
    ap.add_argument("--targets", nargs="*", default=None,
                    help="trace only these registry targets")
    ap.add_argument("--rules", nargs="*", default=None,
                    help="run only these rules")
    ap.add_argument("--mutation", metavar="NAME",
                    help="analyze one seeded mutant instead of the tree")
    ap.add_argument("--no-trace", action="store_true",
                    help="source rules only (skip jaxpr tracing)")
    ap.add_argument("--list", action="store_true",
                    help="list rules, targets, and mutations, then exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print INFO findings")
    args = ap.parse_args(argv)

    from repro.analysis import (
        analyze_mutation, analyze_sources, run_analysis,
    )

    if args.list:
        from repro.analysis.mutations import MUTATION_NAMES
        from repro.analysis.rules import ALL_RULES
        from repro.analysis.targets import TARGETS
        print("rules:    ", " ".join(r.name for r in ALL_RULES))
        print("targets:  ", " ".join(sorted(TARGETS)))
        print("mutations:", " ".join(MUTATION_NAMES))
        return 0

    if args.mutation:
        report = analyze_mutation(args.mutation, rules=args.rules)
    elif args.no_trace:
        report = analyze_sources(args.paths or ["src/repro"], rules=args.rules)
    else:
        report = run_analysis(
            paths=args.paths or None, targets=args.targets, rules=args.rules,
        )

    if args.json == "-":
        print(report.to_json())
    else:
        print(report.render(verbose=args.verbose))
        if args.json:
            Path(args.json).write_text(report.to_json() + "\n")
            print(f"json report -> {args.json}")

    return 0 if report.clean else 1


if __name__ == "__main__":
    try:
        code = main()
    except Exception as exc:  # crash != caught: CI tells them apart
        print(f"analyzer crashed: {type(exc).__name__}: {exc}",
              file=sys.stderr)
        code = 2
    sys.exit(code)
