#!/usr/bin/env python
"""Seeded fuzzer for the single-pass reservation protocol.

Each iteration draws one adversarial graph instance (hubs, duplicate
edges, self-loops, invalid slots — always at a FIXED padded shape so the
jitted production entry points compile exactly once) and runs it through:

* ``apram_sweep`` — the scheduler zoo (stream, hub-contention,
  round-robin, seeded-random) through the fully-checked step-level APRAM
  model (``repro.testing.apram``);
* ``skipper_conformance`` — ``core/skipper.skipper`` mask pinned as a
  reachable APRAM trace (``repro.testing.oracle.pin_trace``);
* ``sgmm_conformance`` — the sequential-greedy oracle mask pinned the
  same way (and cross-checked equal to the stream-order model run);
* ``bmatch_conformance`` — ``core/bipartite.bmatch_assign`` at
  budget=1/capacity=1 pinned via the bipartite stream mapping.

On failure the instance is SHRUNK (greedy edge invalidation — slots are
replaced with ``-1`` padding, never removed, so shapes stay fixed) and
the minimized counterexample is written as JSON to ``--artifacts``.

``--mutation NAME`` seeds a protocol bug into the model
(``repro.testing.apram.MUTATIONS``); the conformance checks are skipped
(they pin the *real* production code, which a model mutation cannot
break) and the run must exit 1 — CI uses this as the canary proving the
fuzzer can actually fail.

``--replay PATH...`` re-runs saved counterexamples (files or directories
of ``*.json``) instead of fuzzing; the checked-in regression corpus in
``tests/fuzz_corpus/`` is replayed this way by the test suite.

Exit codes: 0 clean, 1 counterexample found (or replay failure), 2
harness error.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.testing import (  # noqa: E402
    ApramViolation,
    ConformanceError,
    MUTATIONS,
    bipartite_stream,
    pin_trace,
    sweep,
)

# Fixed instance shape: every jitted entry point compiles once per run.
NUM_VERTICES = 64
NUM_EDGES = 192
BM_TOKENS = 16
BM_EXPERTS = 8
BM_EDGES = 64

CORPUS_VERSION = 1


# --------------------------------------------------------------------------
# instance generation
# --------------------------------------------------------------------------
def make_instance(seed: int):
    """One adversarial graph instance at the fixed padded shape.

    Mixes edge sources so contention shapes the APRAM model is sensitive
    to (hub fan-in, chains, duplicates, self-loops) appear in every
    instance; ``-1`` slots model stream padding.
    """
    rng = np.random.default_rng(seed)
    n = NUM_VERTICES
    m = NUM_EDGES
    hubs = rng.integers(0, 6, m)                       # few hot vertices
    chain = (np.arange(m) % (n - 1))                   # path-like runs
    rand_u = rng.integers(0, n, m)
    rand_v = rng.integers(0, n, m)
    pick = rng.integers(0, 4, m)
    u = np.select([pick == 0, pick == 1, pick == 2], [hubs, chain, rand_u],
                  rand_u)
    v = np.select([pick == 0, pick == 1, pick == 2],
                  [rand_v, chain + 1, rand_v], rand_v)
    dup = rng.random(m) < 0.10                         # duplicate stream slots
    src = rng.integers(0, m, m)
    u = np.where(dup, u[src], u)
    v = np.where(dup, v[src], v)
    loop = rng.random(m) < 0.05                        # self-loops
    v = np.where(loop, u, v)
    pad = rng.random(m) < 0.08                         # invalid padding slots
    u = np.where(pad, -1, u)
    v = np.where(pad, -1, v)
    return u.astype(np.int64), v.astype(np.int64), n


def make_bmatch_instance(seed: int):
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, BM_TOKENS, BM_EDGES).astype(np.int64)
    exp = rng.integers(0, BM_EXPERTS, BM_EDGES).astype(np.int64)
    tok = np.where(rng.random(BM_EDGES) < 0.1, -1, tok)
    return tok, exp


# --------------------------------------------------------------------------
# checks — each raises ApramViolation / ConformanceError on failure
# --------------------------------------------------------------------------
def _edgelist(u, v, n):
    import jax.numpy as jnp

    from repro.graphs.types import EdgeList

    return EdgeList(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32), n)


def check_apram_sweep(u, v, n, *, seed: int, mutation=None):
    sweep((u, v, n), seeds=(seed, seed + 1), threads=(2, 5),
          mutation=mutation, strict=True)


def check_skipper_conformance(u, v, n, *, seed: int, mutation=None):
    from repro.core.skipper import skipper

    res, _ = skipper(_edgelist(u, v, n), tile_size=32)
    pin_trace((u, v, n), np.asarray(res.match_mask), label="skipper")


def check_sgmm_conformance(u, v, n, *, seed: int, mutation=None):
    from repro.core.sgmm import sgmm

    mask = np.asarray(sgmm(_edgelist(u, v, n)).match_mask)
    trace = pin_trace((u, v, n), mask, label="sgmm")
    # sgmm IS the stream-order model run; they must agree exactly
    from repro.testing import run_schedule, stream_order

    model = run_schedule((u, v, n), stream_order(len(u)))
    if not np.array_equal(model.matched, mask):
        k = int(np.flatnonzero(model.matched != mask)[0])
        raise ConformanceError(
            f"sgmm diverges from the stream-order APRAM run at index {k}",
            first_mismatch=k,
        )
    del trace


def check_bmatch_conformance(u, v, n, *, seed: int, mutation=None):
    # u/v are ignored — the bmatch stream has its own fixed shape
    import jax.numpy as jnp

    from repro.core.bipartite import bmatch_assign

    tok, exp = make_bmatch_instance(seed)
    accept = np.asarray(bmatch_assign(
        jnp.asarray(tok, jnp.int32), jnp.asarray(exp, jnp.int32),
        num_tokens=BM_TOKENS, num_experts=BM_EXPERTS,
        token_budget=1, expert_capacity=1, tile_size=16,
    ))
    stream = bipartite_stream(tok, exp, num_tokens=BM_TOKENS,
                              num_experts=BM_EXPERTS)
    pin_trace(stream, accept, label="bmatch")


CHECKS = {
    "apram_sweep": check_apram_sweep,
    "skipper_conformance": check_skipper_conformance,
    "sgmm_conformance": check_sgmm_conformance,
    "bmatch_conformance": check_bmatch_conformance,
}
#: checks that exercise the model itself and honor ``mutation=``
MODEL_CHECKS = ("apram_sweep",)


# --------------------------------------------------------------------------
# shrinking + corpus
# --------------------------------------------------------------------------
def _fails(check, u, v, n, seed, mutation):
    try:
        CHECKS[check](u, v, n, seed=seed, mutation=mutation)
        return None
    except (ApramViolation, ConformanceError) as err:
        return err


def shrink(check: str, u, v, n, *, seed: int, mutation=None,
           max_rounds: int = 8):
    """Greedy minimization: invalidate one stream slot at a time (set it
    to ``-1`` padding — shapes never change) and keep the removal while
    the check still fails. Quadratic but the instances are tiny."""
    u, v = u.copy(), v.copy()
    for _ in range(max_rounds):
        progressed = False
        for i in range(len(u)):
            if u[i] == -1 and v[i] == -1:
                continue
            su, sv = u[i], v[i]
            u[i] = v[i] = -1
            if _fails(check, u, v, n, seed, mutation) is None:
                u[i], v[i] = su, sv        # removal heals it: keep the edge
            else:
                progressed = True
        if not progressed:
            break
    return u, v


def counterexample_record(check, u, v, n, *, seed, mutation, error):
    live = int(((u != -1) | (v != -1)).sum())
    return {
        "version": CORPUS_VERSION,
        "check": check,
        "mutation": mutation,
        "seed": int(seed),
        "num_vertices": int(n),
        "u": [int(x) for x in u],
        "v": [int(x) for x in v],
        "live_edges": live,
        "error": f"{type(error).__name__}: {error}",
    }


def replay_record(rec) -> bool:
    """Re-run one corpus record; True iff it now PASSES."""
    u = np.asarray(rec["u"], np.int64)
    v = np.asarray(rec["v"], np.int64)
    err = _fails(rec["check"], u, v, int(rec["num_vertices"]),
                 int(rec["seed"]), rec.get("mutation"))
    return err is None


def iter_corpus(paths):
    for p in paths:
        p = Path(p)
        files = sorted(p.glob("*.json")) if p.is_dir() else [p]
        for f in files:
            yield f, json.loads(f.read_text())


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def fuzz(args) -> int:
    checks = list(MODEL_CHECKS) if args.mutation else list(CHECKS)
    artifacts = Path(args.artifacts)
    deadline = time.monotonic() + args.time_budget
    found = 0
    it = 0
    while it < args.iterations and time.monotonic() < deadline:
        seed = args.seed + it
        u, v, n = make_instance(seed)
        for check in checks:
            err = _fails(check, u, v, n, seed, args.mutation)
            if err is None:
                continue
            found += 1
            su, sv = shrink(check, u, v, n, seed=seed,
                            mutation=args.mutation)
            rec = counterexample_record(
                check, su, sv, n, seed=seed, mutation=args.mutation,
                error=err)
            artifacts.mkdir(parents=True, exist_ok=True)
            out = artifacts / f"counterexample_{check}_seed{seed}.json"
            out.write_text(json.dumps(rec, indent=1))
            print(f"FAIL {check} seed={seed}: {rec['error']}")
            print(f"  minimized to {rec['live_edges']} live edges -> {out}")
            if found >= args.max_counterexamples:
                print(f"stopping after {found} counterexample(s)")
                return 1
        it += 1
        if args.verbose and it % 10 == 0:
            print(f"... {it} iterations clean "
                  f"({deadline - time.monotonic():.0f}s left)")
    status = "FOUND COUNTEREXAMPLES" if found else "clean"
    print(f"fuzz: {it} iterations x {len(checks)} checks "
          f"(seed base {args.seed}, mutation={args.mutation}): {status}")
    return 1 if found else 0


def replay(args) -> int:
    failed = 0
    total = 0
    for f, rec in iter_corpus(args.replay):
        total += 1
        ok = replay_record(rec)
        print(f"{'ok  ' if ok else 'FAIL'} {f.name} "
              f"({rec['check']}, {rec.get('live_edges', '?')} live edges)")
        failed += 0 if ok else 1
    print(f"replay: {total - failed}/{total} corpus records pass")
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0, help="base seed")
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--time-budget", type=float, default=120.0,
                    help="wall-clock budget in seconds")
    ap.add_argument("--mutation", choices=sorted(MUTATIONS), default=None,
                    help="seed a protocol bug into the model (canary mode; "
                    "model checks only, MUST exit 1)")
    ap.add_argument("--artifacts", default="fuzz_artifacts",
                    help="directory for minimized counterexample JSON")
    ap.add_argument("--max-counterexamples", type=int, default=3)
    ap.add_argument("--replay", nargs="+", default=None, metavar="PATH",
                    help="replay corpus files/dirs instead of fuzzing")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    try:
        return replay(args) if args.replay else fuzz(args)
    except Exception:
        traceback.print_exc()
        return 2


if __name__ == "__main__":
    sys.exit(main())
