"""Render the roofline baseline table from experiments/dryrun/*.json."""
import glob
import json
import os

HERE = os.path.dirname(__file__)


def main():
    rows = []
    for p in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        r = json.load(open(p))
        if r.get("ok"):
            rows.append(r)
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'hbm(corr)':>10s} {'fit':>3s} "
           f"{'dom':>10s} {'t_c ms':>9s} {'t_m ms':>10s} {'t_x ms':>10s} {'useful':>6s} {'mb':>2s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        corr = r.get("hbm_gib_tpu_corrected", r["hbm_gib"])
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} {corr:9.2f}G "
              f"{'Y' if r['fits_hbm'] else 'N':>3s} {r['dominant']:>10s} "
              f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:10.2f} "
              f"{r['collective_s']*1e3:10.2f} {(r['useful_flops_ratio'] or 0):6.3f} "
              f"{r.get('microbatches', 1):>2d}")
    bad = [f"{r['arch']}/{r['shape']}/{r['mesh']}" for r in rows if not r["fits_hbm"]]
    print(f"\ncells: {len(rows)}; not fitting (corrected): {bad if bad else 'none'}")


if __name__ == "__main__":
    main()
