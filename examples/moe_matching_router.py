"""The paper's technique as an MoE router: Skipper b-matching vs top-k.

Shows the capacity behaviour difference: under a skewed router distribution,
top-k overflows hot experts (dropped tokens), while the matching router
fills capacity exactly and spills tokens to their next-best expert — the
single-pass, conflict-resolving assignment from the paper, applied to
token-expert edges.

    PYTHONPATH=src python examples/moe_matching_router.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.bipartite import bmatch_assign


def route_stats(n_tok=2048, n_exp=8, k=2, cap_factor=1.25, skew=2.0, seed=0):
    rng = np.random.default_rng(seed)
    # skewed router logits: a few hot experts
    bias = np.sort(rng.normal(size=n_exp))[::-1] * skew
    scores = rng.normal(size=(n_tok, n_exp)) + bias
    scores = jnp.asarray(scores, jnp.float32)
    cap = int(n_tok * k / n_exp * cap_factor)

    # ---- top-k with capacity truncation (the baseline failure mode)
    vals, idx = jax.lax.top_k(scores, k)
    exp = np.asarray(idx).reshape(-1)
    counts = np.zeros(n_exp, int)
    dropped = 0
    for e in exp:          # arrival order, as capacity buffers fill
        if counts[e] < cap:
            counts[e] += 1
        else:
            dropped += 1
    topk_util = counts.sum() / (n_tok * k)

    # ---- skipper matching router
    kp = min(n_exp, k + 2)
    v2, i2 = jax.lax.top_k(scores, kp)
    tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kp)
    expc = i2.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(-v2.reshape(-1))
    accept = bmatch_assign(
        tok[order], expc[order], num_tokens=n_tok, num_experts=n_exp,
        token_budget=k, expert_capacity=cap,
    )
    acc = np.asarray(accept)
    exp_sorted = np.asarray(expc[order])
    counts_m = np.bincount(exp_sorted[acc], minlength=n_exp)
    match_util = acc.sum() / (n_tok * k)

    print(f"experts={n_exp} k={k} capacity={cap} skew={skew}")
    print(f"  top-k   : assignments={counts.sum():5d} dropped={dropped:5d} "
          f"utilization={topk_util:.3f} max_load={counts.max()}")
    print(f"  skipper : assignments={acc.sum():5d} dropped={0:5d} "
          f"utilization={match_util:.3f} max_load={counts_m.max()} "
          f"(capacity respected by construction)")
    assert counts_m.max() <= cap


def main():
    print("== Mixtral-style: 8 experts, top-2 ==")
    route_stats(n_exp=8, k=2, skew=2.0)
    print("== Granite-style: 40 experts, top-8 ==")
    route_stats(n_exp=40, k=8, skew=2.0)
    print("== pathological skew ==")
    route_stats(n_exp=8, k=2, skew=5.0)


if __name__ == "__main__":
    main()
