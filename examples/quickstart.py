"""Quickstart: Skipper maximal matching on a graph, validated, with the
paper's headline comparisons reproduced in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    FaultPlan, sgmm, skipper, sidmm, bmatch_assign, check_matching,
    conflict_table,
)
from repro.core.distributed import distributed_skipper
from repro.graphs import rmat_graph
from repro.kernels.skipper_match import skipper_match


def main():
    # a Graph500-style RMAT graph (the paper's g500 family), ~1M edges
    g = rmat_graph(scale=14, edge_factor=16, seed=0)
    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}")

    # 1. single-pass Skipper (vectorized tiles, JIT conflict resolution)
    result, conflicts = skipper(g, tile_size=512, with_conflicts=True)
    stats = {k: v.item() for k, v in check_matching(g, result.match_mask).items()}
    print(f"skipper: {stats['num_matches']:,} matches | valid={stats['valid']} "
          f"maximal={stats['maximal']}")
    print(f"  accesses/edge = {float(result.counters.total_accesses)/g.num_edges:.2f} "
          f"(paper band: 1.2-3.4), single pass")

    tbl = conflict_table(np.asarray(conflicts))
    print(f"  JIT conflicts: {tbl['total_cnf']} on {tbl['edges_exp_cnf']} edges "
          f"(ratio {tbl['conflict_ratio']:.5f} — paper: <0.1%)")

    # 2. the baselines it beats
    r_sgmm = sgmm(g)
    r_sidmm = sidmm(g, batch_size=4096)
    print(f"sgmm:   {int(r_sgmm.num_matches):,} matches, "
          f"{float(r_sgmm.counters.total_accesses)/g.num_edges:.2f} accesses/edge")
    print(f"sidmm:  {int(r_sidmm.num_matches):,} matches, "
          f"{float(r_sidmm.counters.total_accesses)/g.num_edges:.2f} accesses/edge, "
          f"{int(r_sidmm.counters.rounds)} rounds (vs skipper's single pass)")

    # 3. multi-device Skipper (devices = the paper's threads)
    result_d, dstats = distributed_skipper(g, block_size=512)
    stats_d = {k: v.item() for k, v in check_matching(g, result_d.match_mask).items()}
    print(f"distributed: {stats_d['num_matches']:,} matches | "
          f"proposals={int(dstats.proposals):,} lost={int(dstats.lost_proposals)} "
          f"requeued={int(dstats.requeued)}")

    # 3b. locality-sharded: reorder + window-partition so each device's
    # round is intra-window work on the device-resident pipeline; only
    # cross-window edges pay the propose/gather/replay protocol
    result_s, sstats = distributed_skipper(g, reorder="degree")
    stats_s = {k: v.item() for k, v in check_matching(g, result_s.match_mask).items()}
    print(f"distributed (locality-sharded): {stats_s['num_matches']:,} matches | "
          f"proposals={int(sstats.proposals):,} (global tier only) "
          f"gathered_bytes={int(sstats.gathered_bytes):,}")

    # 3c. graceful degradation (DESIGN.md §11): inject faults, inspect the
    # damage, recover. At D=1 the retry buffer never fills (requeues only
    # exist when proposals lose a cross-device race), so a truncated retry
    # buffer alone is inert — pair it with dropped proposal packets, the
    # silent failure mode: the sender believes it proposed, so the edge is
    # neither replayed nor requeued and maximality quietly breaks.
    chaos = FaultPlan(seed=7, drop_proposals=0.05, truncate_retry=64)
    result_f, fstats = distributed_skipper(
        g, block_size=512, faults=chaos, on_fault="report",
    )
    stats_f = check_matching(g, result_f.match_mask)
    print(f"faulted (report): maximal={stats_f['maximal'].item()} | "
          f"residual_edges={int(fstats.residual_edges)} "
          f"corrupted_cells={int(fstats.corrupted_cells)} "
          f"retry_overflow={int(fstats.retry_overflow)}")
    result_r, rstats = distributed_skipper(
        g, block_size=512, faults=chaos, on_fault="recover", verify=True,
    )
    stats_r = check_matching(g, result_r.match_mask)
    print(f"recovered: maximal={stats_r['maximal'].item()} | "
          f"attempts={int(rstats.recovery_attempts)} "
          f"replayed={int(rstats.residual_edges)} edges -> "
          f"+{int(rstats.recovered_matches)} matches")

    # 4. the same claim engine, capacitated: MoE b-matching routing of a
    # token batch (DESIGN.md §9) — each token takes <= budget experts, each
    # expert <= capacity tokens, decided in one pass over the score-sorted
    # candidate stream (exactly the sequential greedy, vectorized)
    n_tok, n_exp, budget = 4096, 8, 2
    kp = budget + 2                      # candidates per token
    scores = jax.random.normal(jax.random.PRNGKey(0), (n_tok, n_exp))
    vals, idx = jax.lax.top_k(scores, kp)
    tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), kp)
    exp = idx.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(-vals.reshape(-1))            # best edges first
    cap = int(n_tok * budget / n_exp * 1.25)
    accept, stats = bmatch_assign(
        tok[order], exp[order], num_tokens=n_tok, num_experts=n_exp,
        token_budget=budget, expert_capacity=cap, with_stats=True,
    )
    acc = np.asarray(accept)
    loads = np.bincount(np.asarray(exp[order])[acc], minlength=n_exp)
    print(f"bmatch router: {n_tok:,} tokens x {n_exp} experts (budget {budget}, "
          f"capacity {cap}): {int(acc.sum()):,}/{acc.size:,} candidates accepted | "
          f"max expert load {int(loads.max())} (<= capacity by construction), "
          f"conflicts={int(stats['conflicts'])}")

    # 5. the Pallas TPU kernel (interpret mode on CPU)
    small = rmat_graph(scale=11, edge_factor=8, seed=1)
    r_k = skipper_match(small, window=1024, tile_size=128)
    s_k = {k: v.item() for k, v in check_matching(small, r_k.match_mask).items()}
    print(f"pallas kernel (|E|={small.num_edges:,}): {s_k['num_matches']:,} matches | "
          f"valid={s_k['valid']} maximal={s_k['maximal']}")

    # 6. static kernel conformance (DESIGN.md §14) — the same checks the
    # static-analysis CI job gates on, scoped to the kernel targets here;
    # the full sweep (+ sources, + JSON artifact) is
    #   PYTHONPATH=src python tools/analyze.py src/repro --json report.json
    from repro.analysis import analyze_targets

    report = analyze_targets(["boundary_kernel", "pipeline_kernel"])
    budget = next(f.data for f in report.findings
                  if f.rule == "vmem-budget" and f.data
                  and "total_bytes" in f.data)
    print(f"conformance: {len(report.targets_analyzed)} kernel targets | "
          f"clean={report.clean} | boundary VMEM/step "
          f"{budget['total_bytes'] / 1024:.0f} KiB (V-independent, "
          f"DMA-ordered, one-hot gathers only)")


if __name__ == "__main__":
    main()
