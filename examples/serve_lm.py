"""Batched serving demo: prefill + decode with continuous slot refill over
the qwen1.5-0.5b smoke config.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve


def main():
    outputs = serve(
        "qwen1.5-0.5b", smoke=True,
        num_requests=8, slots=4, prompt_len=32, max_new=12,
    )
    for rid, toks in sorted(outputs.items()):
        print(f"request {rid}: {len(toks)} tokens -> {toks[:8]}{'...' if len(toks) > 8 else ''}")


if __name__ == "__main__":
    main()
