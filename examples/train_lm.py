"""End-to-end driver: train a ~100M-param llama3.2-family model for a few
hundred steps on CPU with checkpointing + matching-based sequence packing.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch.train import train
import repro.configs.registry as registry


# ~100M params: 12L x 768d llama-style with a 32k vocab
LM100M = ModelConfig(
    name="lm-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
    d_ff=2048, vocab_size=32000, tie_embeddings=True,
    dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()

    # register the example config so the driver can resolve it (the driver
    # binds get_config/get_smoke_config at import, so patch its module)
    import repro.launch.train as train_mod

    def fake_get(arch):
        assert arch == "lm-100m"
        return LM100M

    registry.get_config = fake_get
    registry.get_smoke_config = fake_get
    train_mod.get_config = fake_get
    train_mod.get_smoke_config = fake_get

    import math
    import jax
    from repro.launch import adapters
    n = sum(
        math.prod(l.shape)
        for l in jax.tree.leaves(
            jax.eval_shape(lambda: adapters.init_fn(jax.random.PRNGKey(0), LM100M))
        )
    )
    print(f"[example] lm-100m: {n/1e6:.0f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    losses = train(
        "lm-100m", smoke=True, steps=args.steps, batch_size=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir, checkpoint_every=100,
    )
    if losses:
        print(f"[example] loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
